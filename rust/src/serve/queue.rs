//! Overload-aware admission queue + priority scheduler over N virtual NPU
//! instances.
//!
//! Event-driven simulation on the shared virtual clock (see the module doc
//! in `serve/mod.rs` for the determinism contract). Three mechanisms on
//! top of the earliest-idle dispatch core:
//!
//! * **Bounded admission** — the queue holds at most
//!   [`SchedulerOptions::queue_capacity`] requests; overflow is shed per
//!   [`AdmissionPolicy`] (reject the newest arrival, or drop the oldest
//!   queued request to make room). Shed requests never run and are
//!   reported separately, so sustained overload bounds queueing delay
//!   instead of growing it without limit.
//! * **Priority classes** — each [`Request`] carries a [`Priority`];
//!   dispatch picks the pending request with the best
//!   `(effective class, admission order)` key. An optional aging rule
//!   ([`SchedulerOptions::age_after_cycles`]) promotes a waiting request
//!   one class per aging period so low classes cannot starve.
//! * **Same-model batching** — when the head-of-queue request's model and
//!   class match other queued requests, up to
//!   [`SchedulerOptions::max_batch`] of them coalesce onto one instance.
//!   The batch leader pays the full service time; each follower pays only
//!   [`marginal_service_cycles`] (weights already resident, parameter
//!   fetches skipped), so batching raises throughput under backlog at a
//!   bounded latency cost. With [`SchedulerOptions::dynamic_batch`] the
//!   effective ceiling scales with queue depth (static `max_batch` stays
//!   the hard cap), so light load batches little and deep backlog batches
//!   fully.
//!
//! Dispatch-order determinism: the selection key is a pure function of
//! the pending set and the decision time, ties break toward the earliest
//! admission, and equally idle instances break toward the lowest id — no
//! host-clock value ever enters a decision.

use std::collections::HashSet;

use crate::arch::NeutronConfig;
use crate::compiler::TileId;
use crate::coordinator::{Executor, Job, JobProgram, Metrics};
use crate::util::prop::Rng;
use crate::zoo::ModelId;

/// Priority class carried on every request. Lower [`Priority::rank`]
/// values dispatch first; within a class, admission order wins.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Priority {
    /// Interactive traffic: always dispatched before other classes.
    Realtime,
    /// Default class for ordinary requests.
    Standard,
    /// Best-effort background work: yields to everything (until aging
    /// promotes it).
    Batch,
}

impl Priority {
    /// All classes, highest priority first.
    pub fn all() -> [Priority; 3] {
        [Priority::Realtime, Priority::Standard, Priority::Batch]
    }

    /// Dispatch rank: 0 is served first. Aging lowers the effective rank
    /// of a waiting request, never past 0.
    pub fn rank(self) -> u8 {
        match self {
            Priority::Realtime => 0,
            Priority::Standard => 1,
            Priority::Batch => 2,
        }
    }

    /// Human-readable class name (also the trace-format spelling).
    pub fn display_name(self) -> &'static str {
        match self {
            Priority::Realtime => "realtime",
            Priority::Standard => "standard",
            Priority::Batch => "batch",
        }
    }

    /// Parse the [`Priority::display_name`] spelling back.
    pub fn parse(s: &str) -> Option<Priority> {
        let lower = s.to_ascii_lowercase();
        Priority::all().into_iter().find(|p| p.display_name() == lower)
    }
}

/// Relative class weights for synthetic trace generation: each request's
/// class is drawn with probability `weight / total`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PriorityMix {
    /// Weight of [`Priority::Realtime`].
    pub realtime: u32,
    /// Weight of [`Priority::Standard`].
    pub standard: u32,
    /// Weight of [`Priority::Batch`].
    pub batch: u32,
}

impl Default for PriorityMix {
    /// The serving default: 1 realtime : 2 standard : 1 batch.
    fn default() -> Self {
        Self { realtime: 1, standard: 2, batch: 1 }
    }
}

impl PriorityMix {
    /// Every request is [`Priority::Standard`] — the mix that degenerates
    /// to plain FIFO scheduling (no aging, no class reordering).
    pub fn standard_only() -> Self {
        Self { realtime: 0, standard: 1, batch: 0 }
    }

    /// Draw one class; consumes exactly one PRNG value, so traces stay
    /// reproducible. Panics when all weights are zero. Weights sum in
    /// u64, so extreme u32 weights cannot overflow into a wrong
    /// distribution.
    pub fn pick(&self, rng: &mut Rng) -> Priority {
        let (realtime, standard) = (self.realtime as u64, self.standard as u64);
        let total = realtime + standard + self.batch as u64;
        assert!(total > 0, "priority mix needs at least one non-zero weight");
        let draw = rng.int(0, total as i64 - 1) as u64;
        if draw < realtime {
            Priority::Realtime
        } else if draw < realtime + standard {
            Priority::Standard
        } else {
            Priority::Batch
        }
    }
}

/// What to do with an arrival when the admission queue is full.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AdmissionPolicy {
    /// Shed the arriving request itself (the queue keeps its backlog).
    RejectNewest,
    /// Shed the oldest queued request — regardless of class — and admit
    /// the arrival (bounded-staleness semantics: the longest-queued work
    /// is the least likely to still be wanted).
    DropOldest,
}

impl AdmissionPolicy {
    /// Parse from a CLI string.
    pub fn parse(s: &str) -> Option<AdmissionPolicy> {
        match s.to_ascii_lowercase().as_str() {
            "reject-newest" | "reject" => Some(AdmissionPolicy::RejectNewest),
            "drop-oldest" | "drop" => Some(AdmissionPolicy::DropOldest),
            _ => None,
        }
    }

    /// Human-readable policy name (the CLI spelling).
    pub fn display_name(self) -> &'static str {
        match self {
            AdmissionPolicy::RejectNewest => "reject-newest",
            AdmissionPolicy::DropOldest => "drop-oldest",
        }
    }
}

/// Outcome of one [`Scheduler::admit`] call.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Admission {
    /// The request entered the queue.
    Accepted,
    /// The queue was full: the contained request was shed — the arrival
    /// itself under [`AdmissionPolicy::RejectNewest`], the oldest queued
    /// request under [`AdmissionPolicy::DropOldest`].
    Shed(Request),
}

/// Scheduling knobs, grouped so every entry point (CLI, benches, tests)
/// names them once.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SchedulerOptions {
    /// Virtual NPU instances sharing the admission queue (≥ 1).
    pub instances: usize,
    /// Maximum queued (admitted, not yet dispatched) requests. `None`
    /// means unbounded — the PR-1 behavior, where sustained overload
    /// grows latency without limit.
    pub queue_capacity: Option<usize>,
    /// Load-shedding policy applied when the queue is full.
    pub policy: AdmissionPolicy,
    /// Largest same-model, same-class batch one dispatch may coalesce;
    /// `1` disables batching.
    pub max_batch: usize,
    /// Scale the effective batch ceiling with queue depth: a dispatch may
    /// coalesce at most `ceil(backlog / instances)` requests (backlog
    /// includes the dispatch head), capped by the static `max_batch`
    /// ceiling. Light backlog then batches little (latency-friendly) while
    /// deep backlog batches up to the full ceiling (throughput-friendly).
    /// `false` keeps the static `max_batch` for every dispatch.
    pub dynamic_batch: bool,
    /// Starvation-avoidance aging: a waiting request is promoted one
    /// class per this many cycles waited (`None` disables aging and makes
    /// class order strict).
    pub age_after_cycles: Option<u64>,
}

impl Default for SchedulerOptions {
    /// Two instances, unbounded FIFO-per-class queue, no batching, no
    /// aging — the exact PR-1 scheduler when every request is
    /// [`Priority::Standard`].
    fn default() -> Self {
        Self {
            instances: 2,
            queue_capacity: None,
            policy: AdmissionPolicy::RejectNewest,
            max_batch: 1,
            dynamic_batch: false,
            age_after_cycles: None,
        }
    }
}

impl SchedulerOptions {
    fn validate(&self) {
        assert!(self.instances >= 1, "need at least one NPU instance");
        assert!(self.max_batch >= 1, "max_batch must be at least 1 (1 = batching off)");
        if let Some(cap) = self.queue_capacity {
            assert!(cap >= 1, "queue capacity must be at least 1 (use None for unbounded)");
        }
        if let Some(age) = self.age_after_cycles {
            assert!(age >= 1, "age_after_cycles must be at least 1 (use None to disable)");
        }
    }
}

/// One inference request on the virtual clock.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Request {
    /// Caller-assigned id; [`synthetic_trace`] uses the trace index.
    pub id: u64,
    /// Which zoo model to run.
    pub model: ModelId,
    /// Priority class (see [`Priority`]).
    pub priority: Priority,
    /// Arrival time in NPU core cycles on the shared virtual clock.
    pub arrival_cycles: u64,
}

/// Completion record: latency = queueing delay + service time.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Completion {
    /// Id of the completed request.
    pub id: u64,
    /// Model the request ran.
    pub model: ModelId,
    /// Priority class the request carried.
    pub priority: Priority,
    /// Instance that served the request.
    pub instance: usize,
    /// Position inside the dispatched batch: 0 is the leader (or a solo
    /// request), followers count up from 1.
    pub batch_index: u32,
    /// When the request arrived.
    pub arrival_cycles: u64,
    /// When its batch was dispatched onto the instance.
    pub start_cycles: u64,
    /// When this request's result became available (followers finish
    /// staggered, one marginal service time apart).
    pub finish_cycles: u64,
}

impl Completion {
    /// End-to-end latency on the virtual clock.
    pub fn latency_cycles(&self) -> u64 {
        self.finish_cycles - self.arrival_cycles
    }

    /// Time spent waiting in the admission queue.
    pub fn queue_cycles(&self) -> u64 {
        self.start_cycles - self.arrival_cycles
    }

    /// Time from dispatch to this request's finish. For a batch follower
    /// this includes the shared pipeline time ahead of it, so the
    /// decomposition `latency = queue + service` always holds.
    pub fn service_cycles(&self) -> u64 {
        self.finish_cycles - self.start_cycles
    }

    /// Did this request ride a batch as a follower?
    pub fn batched(&self) -> bool {
        self.batch_index > 0
    }
}

/// Largest admissible `mean_gap_cycles` for [`synthetic_trace`]: gaps are
/// drawn uniformly from `[0, 2·mean]`, and `2·mean` must fit the PRNG's
/// signed-integer range. ≈ 4.6e18 cycles — around 146 years at 1 GHz, so
/// the bound never binds for realistic traces; it exists to make the
/// overflow case loud instead of silently clamping the distribution.
pub const MAX_MEAN_GAP_CYCLES: u64 = (i64::MAX / 2) as u64;

/// Deterministic synthetic request trace with every request
/// [`Priority::Standard`]: the model of each request is drawn uniformly
/// from `models`, inter-arrival gaps uniformly from
/// `[0, 2·mean_gap_cycles]` (mean `mean_gap_cycles`). Same inputs →
/// identical trace; arrivals are non-decreasing and ids are `0..requests`.
///
/// Panics when `mean_gap_cycles` exceeds [`MAX_MEAN_GAP_CYCLES`].
pub fn synthetic_trace(
    models: &[ModelId],
    requests: usize,
    mean_gap_cycles: u64,
    seed: u64,
) -> Vec<Request> {
    synthetic_trace_with_mix(models, requests, mean_gap_cycles, seed, &PriorityMix::standard_only())
}

/// [`synthetic_trace`] with the priority class of each request drawn from
/// `mix`. Per request the PRNG is consumed in a fixed order — model,
/// class, gap — so traces are reproducible across runs and machines.
pub fn synthetic_trace_with_mix(
    models: &[ModelId],
    requests: usize,
    mean_gap_cycles: u64,
    seed: u64,
    mix: &PriorityMix,
) -> Vec<Request> {
    assert!(!models.is_empty(), "trace needs at least one model");
    assert!(
        mean_gap_cycles <= MAX_MEAN_GAP_CYCLES,
        "mean_gap_cycles {mean_gap_cycles} exceeds MAX_MEAN_GAP_CYCLES {MAX_MEAN_GAP_CYCLES}"
    );
    let gap_hi = (mean_gap_cycles * 2) as i64;
    let mut rng = Rng::new(seed);
    let mut clock = 0u64;
    (0..requests as u64)
        .map(|id| {
            let model = *rng.choose(models);
            let priority = mix.pick(&mut rng);
            clock = clock.saturating_add(rng.int(0, gap_hi) as u64);
            Request { id, model, priority, arrival_cycles: clock }
        })
        .collect()
}

/// Service time of a batch follower: the program's tick timing
/// ([`JobProgram::service_cycles_where`], the same helper the executor
/// uses for full service times) with every parameter-tile DMA job
/// skipped — the leader already fetched the weights, and they stay
/// resident for the batch — while all compute and all activation traffic
/// is still paid. Dropping DMA cycles can only shrink a tick's
/// `max(compute, dm)`, so the result is always ≤ the full service time.
pub fn marginal_service_cycles(program: &JobProgram) -> u64 {
    let param_tiles: HashSet<TileId> = program
        .jobs
        .iter()
        .filter_map(|j| match j {
            Job::Compute { param_tile, .. } => *param_tile,
            _ => None,
        })
        .collect();
    program.service_cycles_where(|job| match job {
        Job::Dma { tile, .. } => !param_tiles.contains(tile),
        _ => true,
    })
}

/// One virtual NPU instance: a re-entrant executor plus its position on
/// the shared clock.
pub struct NpuInstance {
    /// Stable instance id (also the dispatch tie-breaker).
    pub id: usize,
    executor: Executor,
    /// Clock cycle at which this instance next goes idle.
    pub busy_until_cycles: u64,
    occupied_cycles: u64,
    served: u64,
}

impl NpuInstance {
    /// Aggregate executor metrics (one executor run per dispatched batch;
    /// batch followers replay the leader's program, so they do not run the
    /// executor again).
    pub fn metrics(&self) -> &Metrics {
        &self.executor.metrics
    }

    /// Total cycles this instance was occupied serving dispatches,
    /// including the marginal tail of every batch (utilization numerator).
    pub fn busy_cycles(&self) -> u64 {
        self.occupied_cycles
    }

    /// Requests served, counting every batch member.
    pub fn served(&self) -> u64 {
        self.served
    }
}

/// Internal queue entry: the request plus its admission sequence number.
/// `pending` stays sorted by `seq` (entries are only appended and
/// removed), which makes "oldest" and FIFO-within-class O(1) to define.
struct QueuedRequest {
    request: Request,
    seq: u64,
}

/// A planned dispatch: which pending entry, onto which instance, when.
struct Plan {
    pending_idx: usize,
    instance_idx: usize,
    start_cycles: u64,
}

/// Overload-aware scheduler: bounded admission queue + priority dispatch
/// with aging + same-model batching over N virtual NPU instances.
///
/// Dispatch order is deterministic: among requests that have arrived by
/// the decision time, the lowest `(effective class rank, admission order)`
/// key wins; equally idle instances break toward the lowest id; all
/// timing derives from the simulated program, never the host clock. With
/// the default options and a single-class trace this degenerates to the
/// FIFO earliest-idle scheduler, for which adding instances can only move
/// every completion earlier (the serve property suite checks this).
///
/// The caller resolves the compiled program for the model named by
/// [`Scheduler::next_model`] (usually through the compile cache) and
/// passes it to [`Scheduler::dispatch_next`]; nothing may be admitted
/// between the two calls, or the plan they agree on would change.
///
/// ```
/// use eiq_neutron::arch::NeutronConfig;
/// use eiq_neutron::serve::{CompileCache, Priority, Request, Scheduler, SchedulerOptions};
/// use eiq_neutron::zoo::ModelId;
///
/// let cfg = NeutronConfig::flagship_2tops();
/// let mut cache = CompileCache::for_serving(cfg.clone());
/// let opts = SchedulerOptions { instances: 1, ..SchedulerOptions::default() };
/// let mut scheduler = Scheduler::new(&cfg, &opts);
/// for id in 0..3 {
///     scheduler.admit(Request {
///         id,
///         model: ModelId::MobileNetV3Min,
///         priority: Priority::Standard,
///         arrival_cycles: 0,
///     });
/// }
/// let mut completions = Vec::new();
/// while let Some(model) = scheduler.next_model() {
///     let entry = cache.get(model);
///     completions.extend(scheduler.dispatch_next(model, &entry.program));
/// }
/// assert_eq!(completions.len(), 3);
/// assert!(completions.windows(2).all(|w| w[0].finish_cycles <= w[1].finish_cycles));
/// ```
pub struct Scheduler {
    opts: SchedulerOptions,
    instances: Vec<NpuInstance>,
    pending: Vec<QueuedRequest>,
    shed: Vec<Request>,
    next_seq: u64,
}

impl Scheduler {
    /// Build a scheduler with `opts.instances` fresh executor instances.
    /// Panics when the options are inconsistent (see [`SchedulerOptions`]).
    pub fn new(cfg: &NeutronConfig, opts: &SchedulerOptions) -> Self {
        opts.validate();
        Self {
            opts: opts.clone(),
            instances: (0..opts.instances)
                .map(|id| NpuInstance {
                    id,
                    executor: Executor::with_config(cfg.clone()),
                    busy_until_cycles: 0,
                    occupied_cycles: 0,
                    served: 0,
                })
                .collect(),
            pending: Vec::new(),
            shed: Vec::new(),
            next_seq: 0,
        }
    }

    /// Offer a request to the admission queue. When the queue is at
    /// capacity the configured [`AdmissionPolicy`] decides who is shed;
    /// the victim is recorded in [`Scheduler::shed`] and returned.
    pub fn admit(&mut self, request: Request) -> Admission {
        if let Some(cap) = self.opts.queue_capacity {
            if self.pending.len() >= cap {
                match self.opts.policy {
                    AdmissionPolicy::RejectNewest => {
                        self.shed.push(request);
                        return Admission::Shed(request);
                    }
                    AdmissionPolicy::DropOldest => {
                        // `pending` is seq-sorted, so index 0 is oldest.
                        let victim = self.pending.remove(0).request;
                        self.shed.push(victim);
                        self.push_pending(request);
                        return Admission::Shed(victim);
                    }
                }
            }
        }
        self.push_pending(request);
        Admission::Accepted
    }

    fn push_pending(&mut self, request: Request) {
        let seq = self.next_seq;
        self.next_seq += 1;
        self.pending.push(QueuedRequest { request, seq });
    }

    /// Requests still waiting in the admission queue.
    pub fn queue_len(&self) -> usize {
        self.pending.len()
    }

    /// Every request shed so far, in shedding order.
    pub fn shed(&self) -> &[Request] {
        &self.shed
    }

    /// Effective dispatch rank of a request at `now`: the class rank,
    /// minus one promotion per full aging period waited, floored at the
    /// highest class.
    fn effective_rank(&self, request: &Request, now: u64) -> u8 {
        let base = request.priority.rank();
        match self.opts.age_after_cycles {
            Some(age) => {
                let waited = now.saturating_sub(request.arrival_cycles);
                base - (waited / age).min(base as u64) as u8
            }
            None => base,
        }
    }

    /// Batch ceiling for the dispatch being committed right now: the
    /// static `max_batch`, or — under [`SchedulerOptions::dynamic_batch`]
    /// — `ceil(backlog / instances)` capped by `max_batch`, where the
    /// backlog counts the queued requests plus the dispatch head (already
    /// popped when this runs). A pure function of queue depth, so dynamic
    /// sizing preserves the determinism contract.
    fn effective_max_batch(&self) -> usize {
        if !self.opts.dynamic_batch {
            return self.opts.max_batch;
        }
        let backlog = self.pending.len() + 1;
        let per_instance = (backlog + self.opts.instances - 1) / self.opts.instances;
        per_instance.clamp(1, self.opts.max_batch)
    }

    /// Plan the next dispatch without committing it. The decision time is
    /// `max(earliest instance idle, earliest pending arrival)` — the first
    /// moment an instance is free *and* some request exists — and only
    /// requests that have arrived by then are eligible (the scheduler
    /// cannot see the future).
    fn plan(&self) -> Option<Plan> {
        let min_arrival = self.pending.iter().map(|q| q.request.arrival_cycles).min()?;
        let instance_idx = self
            .instances
            .iter()
            .min_by_key(|i| (i.busy_until_cycles, i.id))
            .expect("at least one instance")
            .id;
        let decision = self.instances[instance_idx].busy_until_cycles.max(min_arrival);
        let pending_idx = self
            .pending
            .iter()
            .enumerate()
            .filter(|(_, q)| q.request.arrival_cycles <= decision)
            .min_by_key(|(_, q)| (self.effective_rank(&q.request, decision), q.seq))
            .map(|(i, _)| i)
            .expect("min_arrival guarantees at least one eligible request");
        Some(Plan { pending_idx, instance_idx, start_cycles: decision })
    }

    /// Model of the request the next [`Scheduler::dispatch_next`] will
    /// serve, so the caller can resolve its compiled program first.
    pub fn next_model(&self) -> Option<ModelId> {
        self.plan().map(|p| self.pending[p.pending_idx].request.model)
    }

    /// Like [`Scheduler::next_model`], but only when that dispatch would
    /// start at or before `horizon_cycles`. The event loop in
    /// `serve::run_trace` uses this to run every service event up to (and
    /// including) an arrival's timestamp before admitting the arrival —
    /// the "service precedes admission at equal times" convention of the
    /// determinism contract.
    pub fn next_model_before(&self, horizon_cycles: u64) -> Option<ModelId> {
        self.plan()
            .filter(|p| p.start_cycles <= horizon_cycles)
            .map(|p| self.pending[p.pending_idx].request.model)
    }

    /// Dispatch the planned request — plus, when batching is enabled and
    /// every other instance is busy past the start time, up to
    /// `max_batch − 1` already-arrived followers of the same model and
    /// class — onto the earliest-idle instance. `model` and `program` are
    /// the model the caller resolved via [`Scheduler::next_model`] and its
    /// compiled program; if the plan has changed since (something was
    /// admitted in between), the mismatch panics instead of silently
    /// replaying the wrong model's timing. Returns the batch's
    /// completions in batch order (empty when nothing is pending).
    pub fn dispatch_next(&mut self, model: ModelId, program: &JobProgram) -> Vec<Completion> {
        let Some(plan) = self.plan() else { return Vec::new() };
        assert_eq!(
            self.pending[plan.pending_idx].request.model, model,
            "dispatch_next model mismatch: the plan changed between next_model() and \
             dispatch_next() (never admit between the two calls)"
        );
        let head = self.pending.remove(plan.pending_idx).request;
        let start = plan.start_cycles;
        let idx = plan.instance_idx;

        // Batching is a backlog optimization: coalesce only when no other
        // instance is idle at the start time (a free instance would serve
        // a follower sooner than the batch's marginal tail).
        let others_busy = self
            .instances
            .iter()
            .all(|i| i.id == idx || i.busy_until_cycles > start);
        let batch_cap = self.effective_max_batch();
        let mut followers: Vec<Request> = Vec::new();
        if batch_cap > 1 && others_busy {
            // `pending` is seq-sorted, so iteration order = admission order.
            let picked: Vec<usize> = self
                .pending
                .iter()
                .enumerate()
                .filter(|(_, q)| {
                    q.request.model == head.model
                        && q.request.priority == head.priority
                        && q.request.arrival_cycles <= start
                })
                .map(|(i, _)| i)
                .take(batch_cap - 1)
                .collect();
            for &i in picked.iter().rev() {
                followers.push(self.pending.remove(i).request);
            }
            followers.reverse();
        }

        let result = self.instances[idx]
            .executor
            .run_program(program, None)
            .expect("sim-only dispatch cannot fail");
        let full = result.sim_cycles;
        let mut finish = start + full;
        let mut completions = Vec::with_capacity(1 + followers.len());
        completions.push(Completion {
            id: head.id,
            model: head.model,
            priority: head.priority,
            instance: idx,
            batch_index: 0,
            arrival_cycles: head.arrival_cycles,
            start_cycles: start,
            finish_cycles: finish,
        });
        if !followers.is_empty() {
            // Followers replay the resident program: parameter fetches are
            // skipped, and a floor of one cycle keeps service times
            // positive for degenerate programs.
            let marginal = marginal_service_cycles(program).max(1);
            for (j, r) in followers.iter().enumerate() {
                finish += marginal;
                completions.push(Completion {
                    id: r.id,
                    model: r.model,
                    priority: r.priority,
                    instance: idx,
                    batch_index: (j + 1) as u32,
                    arrival_cycles: r.arrival_cycles,
                    start_cycles: start,
                    finish_cycles: finish,
                });
            }
        }
        let instance = &mut self.instances[idx];
        instance.busy_until_cycles = finish;
        instance.occupied_cycles += finish - start;
        instance.served += completions.len() as u64;
        completions
    }

    /// Clock cycle when the last instance goes idle (0 if nothing ran).
    pub fn makespan_cycles(&self) -> u64 {
        self.instances
            .iter()
            .map(|i| i.busy_until_cycles)
            .max()
            .unwrap_or(0)
    }

    /// The virtual NPU instances, indexed by id.
    pub fn instances(&self) -> &[NpuInstance] {
        &self.instances
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::{Format, TransferKind};
    use crate::coordinator::Job;
    use crate::ir::OpId;

    fn toy_program(cycles: u64) -> JobProgram {
        JobProgram {
            jobs: vec![
                Job::Compute {
                    op: OpId(0),
                    out_tile: TileId(0),
                    in_tiles: Vec::new(),
                    param_tile: None,
                    format: Format::Depth,
                    cycles,
                },
                Job::Barrier,
            ],
            model: "toy".to_string(),
        }
    }

    /// Two-tick program with a 600-cycle parameter prologue fetch, a
    /// 1000-cycle compute and a 300-cycle activation fetch:
    /// full = 600 + max(1000, 300) = 1600, marginal = max(1000, 300) = 1000.
    fn weighted_program() -> JobProgram {
        JobProgram {
            jobs: vec![
                Job::Dma {
                    tile: TileId(9),
                    kind: TransferKind::Fetch,
                    bytes: 4_096,
                    cycles: 600,
                },
                Job::Barrier,
                Job::Dma {
                    tile: TileId(1),
                    kind: TransferKind::Fetch,
                    bytes: 1_024,
                    cycles: 300,
                },
                Job::Compute {
                    op: OpId(0),
                    out_tile: TileId(0),
                    in_tiles: vec![TileId(1)],
                    param_tile: Some(TileId(9)),
                    format: Format::Depth,
                    cycles: 1_000,
                },
                Job::Barrier,
            ],
            model: "weighted".to_string(),
        }
    }

    fn request(id: u64, priority: Priority, arrival: u64) -> Request {
        Request { id, model: ModelId::MobileNetV1, priority, arrival_cycles: arrival }
    }

    fn fifo_opts(instances: usize) -> SchedulerOptions {
        SchedulerOptions { instances, ..SchedulerOptions::default() }
    }

    #[test]
    fn trace_is_deterministic_and_ordered() {
        let models = [ModelId::MobileNetV1, ModelId::MobileNetV2];
        let a = synthetic_trace(&models, 50, 1_000, 42);
        let b = synthetic_trace(&models, 50, 1_000, 42);
        assert_eq!(a, b);
        assert!(a.windows(2).all(|w| w[0].arrival_cycles <= w[1].arrival_cycles));
        assert!(a.windows(2).all(|w| w[0].id + 1 == w[1].id));
        assert!(a.iter().all(|r| r.priority == Priority::Standard));
        let c = synthetic_trace(&models, 50, 1_000, 43);
        assert_ne!(a, c);
    }

    #[test]
    fn mixed_trace_draws_all_classes() {
        let models = [ModelId::MobileNetV1];
        let mix = PriorityMix::default();
        let t = synthetic_trace_with_mix(&models, 200, 1_000, 5, &mix);
        for p in Priority::all() {
            assert!(
                t.iter().any(|r| r.priority == p),
                "class {p:?} missing from a 200-request default-mix trace"
            );
        }
        // Degenerate weights pin the class.
        let rt = PriorityMix { realtime: 1, standard: 0, batch: 0 };
        let t = synthetic_trace_with_mix(&models, 50, 1_000, 5, &rt);
        assert!(t.iter().all(|r| r.priority == Priority::Realtime));
    }

    #[test]
    #[should_panic(expected = "exceeds MAX_MEAN_GAP_CYCLES")]
    fn oversized_mean_gap_is_rejected_loudly() {
        synthetic_trace(&[ModelId::MobileNetV1], 1, MAX_MEAN_GAP_CYCLES + 1, 0);
    }

    #[test]
    fn fifo_earliest_idle_dispatch() {
        let cfg = NeutronConfig::flagship_2tops();
        let mut s = Scheduler::new(&cfg, &fifo_opts(2));
        let p = toy_program(1_000);
        for id in 0..4 {
            assert_eq!(s.admit(request(id, Priority::Standard, 0)), Admission::Accepted);
        }
        assert_eq!(s.queue_len(), 4);
        let mut done = Vec::new();
        while s.next_model().is_some() {
            done.extend(s.dispatch_next(ModelId::MobileNetV1, &p));
        }
        // 4 × 1000-cycle requests over 2 instances: two waves.
        assert_eq!(done.len(), 4);
        assert_eq!(done[0].instance, 0, "tie breaks toward the lowest id");
        assert_eq!(done[1].instance, 1);
        assert_eq!(done[0].finish_cycles, 1_000);
        assert_eq!(done[2].start_cycles, 1_000);
        assert_eq!(s.makespan_cycles(), 2_000);
        assert_eq!(done.iter().map(|c| c.latency_cycles()).max().unwrap(), 2_000);
        assert_eq!(s.instances()[0].served() + s.instances()[1].served(), 4);
        assert_eq!(s.instances()[0].metrics().requests, 2);
        assert_eq!(s.instances()[0].busy_cycles(), 2_000);
        assert!(s.shed().is_empty());
    }

    #[test]
    fn latency_is_queue_plus_service() {
        let cfg = NeutronConfig::flagship_2tops();
        let mut s = Scheduler::new(&cfg, &fifo_opts(1));
        let p = toy_program(500);
        s.admit(request(0, Priority::Standard, 100));
        s.admit(request(1, Priority::Standard, 150));
        let a = s.dispatch_next(ModelId::MobileNetV1, &p)[0];
        let b = s.dispatch_next(ModelId::MobileNetV1, &p)[0];
        // The idle instance waits for the arrival; nothing starts early.
        assert_eq!(a.start_cycles, 100);
        assert_eq!(a.finish_cycles, 600);
        assert_eq!(a.queue_cycles(), 0);
        assert_eq!(b.start_cycles, 600);
        assert_eq!(b.queue_cycles(), 450);
        assert_eq!(b.latency_cycles(), b.queue_cycles() + b.service_cycles());
        assert_eq!(s.makespan_cycles(), 1_100);
    }

    #[test]
    fn empty_scheduler_reports_zero_makespan() {
        let cfg = NeutronConfig::flagship_2tops();
        let mut s = Scheduler::new(&cfg, &fifo_opts(3));
        assert_eq!(s.makespan_cycles(), 0);
        assert!(s.next_model().is_none());
        assert!(s.next_model_before(u64::MAX).is_none());
        assert!(s.dispatch_next(ModelId::MobileNetV1, &toy_program(1)).is_empty());
    }

    #[test]
    fn classes_dispatch_in_rank_then_admission_order() {
        let cfg = NeutronConfig::flagship_2tops();
        let mut s = Scheduler::new(&cfg, &fifo_opts(1));
        let p = toy_program(100);
        s.admit(request(0, Priority::Batch, 0));
        s.admit(request(1, Priority::Realtime, 0));
        s.admit(request(2, Priority::Standard, 0));
        s.admit(request(3, Priority::Realtime, 0));
        let mut order = Vec::new();
        while s.next_model().is_some() {
            order.extend(s.dispatch_next(ModelId::MobileNetV1, &p).iter().map(|c| c.id));
        }
        assert_eq!(order, vec![1, 3, 2, 0], "class rank first, admission order within class");
    }

    #[test]
    fn scheduler_cannot_dispatch_requests_before_they_arrive() {
        let cfg = NeutronConfig::flagship_2tops();
        let mut s = Scheduler::new(&cfg, &fifo_opts(1));
        let p = toy_program(100);
        // A Realtime request that arrives at t=500 must not outrank a
        // Standard request already waiting at t=0: at the decision time
        // (t=0, instance idle) only the Standard request has arrived.
        s.admit(request(0, Priority::Standard, 0));
        s.admit(request(1, Priority::Realtime, 500));
        let a = s.dispatch_next(ModelId::MobileNetV1, &p)[0];
        assert_eq!(a.id, 0);
        assert_eq!(a.start_cycles, 0);
        let b = s.dispatch_next(ModelId::MobileNetV1, &p)[0];
        assert_eq!(b.id, 1);
        assert_eq!(b.start_cycles, 500, "idle instance waits for the arrival");
    }

    #[test]
    fn aging_promotes_starved_batch_work() {
        let cfg = NeutronConfig::flagship_2tops();
        let p = toy_program(1_000);
        let run = |age: Option<u64>| {
            let opts = SchedulerOptions {
                instances: 1,
                age_after_cycles: age,
                ..SchedulerOptions::default()
            };
            let mut s = Scheduler::new(&cfg, &opts);
            // Occupy the instance until t=1000, with a Batch request queued
            // from t=0 and a Realtime request arriving just before the
            // instance frees up.
            s.admit(request(0, Priority::Standard, 0));
            s.dispatch_next(ModelId::MobileNetV1, &p);
            s.admit(request(1, Priority::Batch, 0));
            s.admit(request(2, Priority::Realtime, 999));
            s.dispatch_next(ModelId::MobileNetV1, &p)[0].id
        };
        // Strict classes: Realtime jumps the 1000-cycle-old Batch request.
        assert_eq!(run(None), 2);
        // Aging 100 cycles/class: by t=1000 the Batch request has been
        // promoted to effective Realtime and its earlier admission wins.
        assert_eq!(run(Some(100)), 1);
    }

    #[test]
    fn bounded_queue_reject_newest_sheds_the_arrival() {
        let cfg = NeutronConfig::flagship_2tops();
        let opts = SchedulerOptions {
            instances: 1,
            queue_capacity: Some(2),
            policy: AdmissionPolicy::RejectNewest,
            ..SchedulerOptions::default()
        };
        let mut s = Scheduler::new(&cfg, &opts);
        assert_eq!(s.admit(request(0, Priority::Standard, 0)), Admission::Accepted);
        assert_eq!(s.admit(request(1, Priority::Standard, 0)), Admission::Accepted);
        let r2 = request(2, Priority::Standard, 10);
        assert_eq!(s.admit(r2), Admission::Shed(r2));
        assert_eq!(s.queue_len(), 2);
        assert_eq!(s.shed(), &[r2]);
        // The backlog is preserved: ids 0 and 1 still dispatch.
        let p = toy_program(100);
        assert_eq!(s.dispatch_next(ModelId::MobileNetV1, &p)[0].id, 0);
        assert_eq!(s.dispatch_next(ModelId::MobileNetV1, &p)[0].id, 1);
    }

    #[test]
    fn bounded_queue_drop_oldest_sheds_the_head() {
        let cfg = NeutronConfig::flagship_2tops();
        let opts = SchedulerOptions {
            instances: 1,
            queue_capacity: Some(2),
            policy: AdmissionPolicy::DropOldest,
            ..SchedulerOptions::default()
        };
        let mut s = Scheduler::new(&cfg, &opts);
        let r0 = request(0, Priority::Standard, 0);
        s.admit(r0);
        s.admit(request(1, Priority::Standard, 0));
        assert_eq!(s.admit(request(2, Priority::Standard, 10)), Admission::Shed(r0));
        assert_eq!(s.queue_len(), 2);
        assert_eq!(s.shed(), &[r0]);
        let p = toy_program(100);
        assert_eq!(s.dispatch_next(ModelId::MobileNetV1, &p)[0].id, 1);
        assert_eq!(s.dispatch_next(ModelId::MobileNetV1, &p)[0].id, 2);
    }

    #[test]
    fn marginal_cycles_skip_parameter_fetches_only() {
        assert_eq!(marginal_service_cycles(&toy_program(700)), 700);
        let p = weighted_program();
        assert_eq!(marginal_service_cycles(&p), 1_000);
        // Sanity: the executor's full service time is 600 + 1000.
        let cfg = NeutronConfig::flagship_2tops();
        let mut ex = Executor::with_config(cfg);
        let full = ex.run_program(&p, None).unwrap().sim_cycles;
        assert_eq!(full, 1_600);
    }

    #[test]
    fn batching_coalesces_same_model_requests_under_backlog() {
        let cfg = NeutronConfig::flagship_2tops();
        let opts = SchedulerOptions {
            instances: 1,
            max_batch: 3,
            ..SchedulerOptions::default()
        };
        let mut s = Scheduler::new(&cfg, &opts);
        let p = weighted_program();
        for id in 0..4 {
            s.admit(request(id, Priority::Standard, 0));
        }
        // First dispatch: a full batch of 3 (leader 1600, followers +1000).
        let batch = s.dispatch_next(ModelId::MobileNetV1, &p);
        assert_eq!(batch.len(), 3);
        assert_eq!(
            batch.iter().map(|c| (c.id, c.batch_index, c.finish_cycles)).collect::<Vec<_>>(),
            vec![(0, 0, 1_600), (1, 1, 2_600), (2, 2, 3_600)]
        );
        assert!(batch.iter().all(|c| c.start_cycles == 0));
        assert!(!batch[0].batched() && batch[1].batched());
        // Second dispatch: the leftover request rides solo.
        let solo = s.dispatch_next(ModelId::MobileNetV1, &p);
        assert_eq!(solo.len(), 1);
        assert_eq!((solo[0].id, solo[0].start_cycles, solo[0].finish_cycles), (3, 3_600, 5_200));
        // Batched makespan 5200 beats 4 solo services (4 × 1600 = 6400).
        assert_eq!(s.makespan_cycles(), 5_200);
        assert_eq!(s.instances()[0].served(), 4);
        assert_eq!(s.instances()[0].busy_cycles(), 5_200);
        // The executor ran once per batch, not once per request.
        assert_eq!(s.instances()[0].metrics().requests, 2);
    }

    #[test]
    fn batching_defers_to_an_idle_instance() {
        let cfg = NeutronConfig::flagship_2tops();
        let opts = SchedulerOptions {
            instances: 2,
            max_batch: 4,
            ..SchedulerOptions::default()
        };
        let mut s = Scheduler::new(&cfg, &opts);
        let p = weighted_program();
        s.admit(request(0, Priority::Standard, 0));
        s.admit(request(1, Priority::Standard, 0));
        // Instance 1 is idle at t=0, so the first dispatch must not absorb
        // request 1 as a follower — it runs in parallel instead.
        let first = s.dispatch_next(ModelId::MobileNetV1, &p);
        assert_eq!(first.len(), 1);
        let second = s.dispatch_next(ModelId::MobileNetV1, &p);
        assert_eq!(second.len(), 1);
        assert_eq!(second[0].instance, 1);
        assert_eq!(s.makespan_cycles(), 1_600);
    }

    #[test]
    fn priority_parse_round_trips() {
        for p in Priority::all() {
            assert_eq!(Priority::parse(p.display_name()), Some(p));
        }
        assert_eq!(Priority::parse("REALTIME"), Some(Priority::Realtime));
        assert_eq!(Priority::parse("nope"), None);
    }

    #[test]
    fn dynamic_batch_scales_ceiling_with_backlog() {
        let cfg = NeutronConfig::flagship_2tops();
        let opts = SchedulerOptions {
            instances: 1,
            max_batch: 4,
            dynamic_batch: true,
            ..SchedulerOptions::default()
        };
        let p = weighted_program();

        // Shallow backlog (2 queued): ceiling = ceil(2/1) = 2 < max_batch,
        // so only one follower coalesces even though 4 would fit.
        let mut s = Scheduler::new(&cfg, &opts);
        s.admit(request(0, Priority::Standard, 0));
        s.admit(request(1, Priority::Standard, 0));
        assert_eq!(s.dispatch_next(ModelId::MobileNetV1, &p).len(), 2);

        // Deep backlog (8 queued): ceiling = min(8, max_batch) = 4.
        let mut s = Scheduler::new(&cfg, &opts);
        for id in 0..8 {
            s.admit(request(id, Priority::Standard, 0));
        }
        let batch = s.dispatch_next(ModelId::MobileNetV1, &p);
        assert_eq!(batch.len(), 4, "deep backlog reaches the static ceiling");
        assert_eq!(s.queue_len(), 4);

        // Static batching at the same depth behaves identically at the
        // ceiling (dynamic sizing never exceeds max_batch).
        let static_opts = SchedulerOptions { dynamic_batch: false, ..opts.clone() };
        let mut s2 = Scheduler::new(&cfg, &static_opts);
        for id in 0..8 {
            s2.admit(request(id, Priority::Standard, 0));
        }
        assert_eq!(s2.dispatch_next(ModelId::MobileNetV1, &p).len(), 4);
    }

    #[test]
    fn dynamic_batch_divides_backlog_across_instances() {
        let cfg = NeutronConfig::flagship_2tops();
        let opts = SchedulerOptions {
            instances: 2,
            max_batch: 8,
            dynamic_batch: true,
            ..SchedulerOptions::default()
        };
        let p = weighted_program();
        let mut s = Scheduler::new(&cfg, &opts);
        // Occupy both instances with staggered finish times so the next
        // dispatch (on the earlier-idle instance) still sees the other one
        // busy — the condition batching is gated on.
        s.admit(request(100, Priority::Standard, 0));
        s.admit(request(101, Priority::Standard, 0));
        s.dispatch_next(ModelId::MobileNetV1, &toy_program(5_000));
        s.dispatch_next(ModelId::MobileNetV1, &toy_program(2_000));
        for id in 0..6 {
            s.admit(request(id, Priority::Standard, 0));
        }
        // Backlog 6 over 2 instances → ceiling ceil(6/2) = 3.
        let batch = s.dispatch_next(ModelId::MobileNetV1, &p);
        assert_eq!(batch.len(), 3, "backlog is split across the fleet, not hoarded");
        assert_eq!(batch[0].instance, 1, "earliest-idle instance serves the batch");
    }

    #[test]
    fn batching_respects_class_and_model_boundaries() {
        let cfg = NeutronConfig::flagship_2tops();
        let opts = SchedulerOptions {
            instances: 1,
            max_batch: 8,
            ..SchedulerOptions::default()
        };
        let mut s = Scheduler::new(&cfg, &opts);
        let p = weighted_program();
        s.admit(request(0, Priority::Standard, 0));
        s.admit(Request {
            id: 1,
            model: ModelId::MobileNetV2,
            priority: Priority::Standard,
            arrival_cycles: 0,
        });
        s.admit(request(2, Priority::Batch, 0));
        s.admit(request(3, Priority::Standard, 0));
        let batch = s.dispatch_next(ModelId::MobileNetV1, &p);
        // Only id 3 matches the leader's (model, class); the other-model
        // and other-class requests stay queued.
        assert_eq!(batch.iter().map(|c| c.id).collect::<Vec<_>>(), vec![0, 3]);
        assert_eq!(s.queue_len(), 2);
    }
}
