//! Serving front-end: synthetic trace → compile cache → overload-aware
//! scheduler → [`ServeReport`].
//!
//! [`run_trace`] is the event loop that enforces the virtual-clock event
//! order (all service events at or before an arrival's timestamp run
//! before the arrival is admitted); [`serve`] / [`serve_with_cache`] wrap
//! it with trace generation and report building.

use crate::arch::NeutronConfig;
use crate::energy::{fj_to_joules, EnergyModel};
use crate::trace::TraceRecorder;
use crate::zoo::ModelId;

use super::cache::CompileCache;
use super::queue::{
    synthetic_decode_trace, synthetic_trace_with_mix, Completion, Priority, PriorityMix,
    Request, Scheduler, SchedulerOptions,
};

/// Serving scenario parameters: the trace shape plus the scheduler knobs.
#[derive(Debug, Clone)]
pub struct ServeOptions {
    /// Tenant model mix (requests draw uniformly from this list).
    pub models: Vec<ModelId>,
    /// Offered requests in the synthetic trace.
    pub requests: usize,
    /// Mean inter-arrival gap on the virtual clock, cycles.
    pub mean_gap_cycles: u64,
    /// Trace PRNG seed (same seed → identical trace → identical report).
    pub seed: u64,
    /// Priority-class weights for the synthetic trace.
    pub priority_mix: PriorityMix,
    /// Admission, priority and batching configuration.
    pub scheduler: SchedulerOptions,
    /// Generate an autoregressive decode trace instead of single-shot
    /// inference requests: every request prefills `prompt_tokens` and
    /// generates `decode_tokens` tokens. Every model in `models` must be
    /// decode-capable ([`ModelId::decode_config`]).
    pub decode: bool,
    /// Prompt length per decode request, tokens (decode traces only).
    pub prompt_tokens: u32,
    /// Tokens generated per decode request, counting the prefill's first
    /// token (decode traces only).
    pub decode_tokens: u32,
    /// Context-length budget per sequence: `prompt_tokens + decode_tokens`
    /// must fit (validated before the trace is generated). The compiled
    /// bucket ladder covers the KV lengths the trace actually reaches.
    pub max_context: u32,
}

impl Default for ServeOptions {
    fn default() -> Self {
        Self {
            models: vec![
                ModelId::MobileNetV2,
                ModelId::MobileNetV1,
                ModelId::EfficientNetLite0,
            ],
            requests: 200,
            // ~0.6 ms at 1 GHz: keeps two instances around 80% busy on
            // the ~1 ms default model mix.
            mean_gap_cycles: 600_000,
            seed: 7,
            priority_mix: PriorityMix::default(),
            scheduler: SchedulerOptions::default(),
            decode: false,
            prompt_tokens: 8,
            decode_tokens: 8,
            max_context: 32,
        }
    }
}

/// Per-model serving statistics.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelStats {
    /// The model these rows describe.
    pub model: ModelId,
    /// Completed requests for this model.
    pub requests: u64,
    /// Cycles this model kept instances occupied (utilization numerator;
    /// batch followers count only their marginal tail).
    pub busy_cycles: u64,
    /// Mean end-to-end latency of this model's requests, milliseconds.
    pub mean_latency_ms: f64,
}

/// Per-priority-class serving statistics.
#[derive(Debug, Clone, PartialEq)]
pub struct ClassStats {
    /// The priority class these rows describe.
    pub priority: Priority,
    /// Completed requests in this class.
    pub completed: u64,
    /// Requests of this class shed by admission control.
    pub shed: u64,
    /// Mean end-to-end latency, milliseconds (0 when none completed).
    pub mean_latency_ms: f64,
    /// 99th-percentile end-to-end latency, milliseconds.
    pub p99_ms: f64,
    /// 99.9th-percentile end-to-end latency, milliseconds — the tail the
    /// trace-replay tooling compares against recorded tails.
    pub p999_ms: f64,
}

/// Everything a trace run produced: completions, shed requests and
/// per-instance occupancy.
///
/// `completions` are in dispatch order, with each batch contiguous
/// (leader first, followers in admission order) — report builders rely on
/// that contiguity to attribute batch-marginal occupancy.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TraceOutcome {
    /// Completed requests in dispatch order.
    pub completions: Vec<Completion>,
    /// Requests shed by admission control, in shedding order.
    pub shed: Vec<Request>,
    /// Cycles each instance spent occupied, indexed by instance id.
    pub per_instance_busy_cycles: Vec<u64>,
    /// Parameter-tile TCM residency hits across instances (0 with
    /// residency off).
    pub residency_hits: u64,
    /// Parameter-tile TCM residency misses across instances.
    pub residency_misses: u64,
    /// Residency evictions across instances.
    pub residency_evictions: u64,
    /// Dispatches that found every parameter tile already resident.
    pub warm_dispatches: u64,
    /// Head-fetch cycles hidden inside predecessors' fetch-free tails by
    /// intra-instance pipelining (0 with pipelining off).
    pub overlap_cycles: u64,
    /// KV-cache residency entries evicted by other tenants' installs
    /// (capacity preemption; 0 without decode requests or with residency
    /// off).
    pub kv_evictions: u64,
    /// Tokens generated: `decode_tokens` per decode request, 1 per
    /// single-shot inference.
    pub tokens_generated: u64,
}

/// Aggregate serving report. Fully determined by `(config, options)`: no
/// wall-clock value enters any field, so two runs with the same seed
/// compare equal (see the virtual-clock contract in `serve/mod.rs`).
/// Every `*_cycles` field is in NPU core cycles; every `*_ms` / `*_inf_s`
/// field is derived from cycles via the config's core clock `freq_ghz`.
#[derive(Debug, Clone, PartialEq)]
pub struct ServeReport {
    /// Requests offered by the trace (completed + shed).
    pub offered: u64,
    /// Requests that completed service (the goodput numerator).
    pub completed: u64,
    /// Requests shed by admission control.
    pub shed: u64,
    /// Virtual NPU instances that served the trace.
    pub instances: usize,
    /// Core clock used to convert cycles into seconds.
    pub freq_ghz: f64,
    /// Virtual-clock cycle when the last request finished.
    pub makespan_cycles: u64,
    /// Offered load: trace arrivals per second of arrival span (0 when
    /// the whole trace arrives at cycle 0).
    pub offered_load_inf_s: f64,
    /// Goodput: completed requests per second of makespan.
    pub goodput_inf_s: f64,
    /// Mean end-to-end latency of completed requests, milliseconds.
    pub mean_latency_ms: f64,
    /// Median end-to-end latency, milliseconds.
    pub p50_ms: f64,
    /// 95th-percentile end-to-end latency, milliseconds.
    pub p95_ms: f64,
    /// 99th-percentile end-to-end latency, milliseconds.
    pub p99_ms: f64,
    /// 99.9th-percentile end-to-end latency, milliseconds.
    pub p999_ms: f64,
    /// Mean admission-queue wait, milliseconds.
    pub mean_queue_ms: f64,
    /// Multi-request batches dispatched.
    pub batches: u64,
    /// Requests that rode a batch as a follower (paying only the marginal
    /// service time).
    pub batched_requests: u64,
    /// Compile-cache hits during the run.
    pub cache_hits: u64,
    /// Compile-cache misses (cold compiles) during the run.
    pub cache_misses: u64,
    /// Parameter-tile TCM residency hits (0 with residency off).
    pub residency_hits: u64,
    /// Parameter-tile TCM residency misses.
    pub residency_misses: u64,
    /// TCM residency evictions.
    pub residency_evictions: u64,
    /// Dispatches that found every parameter tile already TCM-resident.
    pub warm_dispatches: u64,
    /// Head-fetch cycles hidden by intra-instance pipelining (0 with
    /// pipelining off).
    pub overlap_cycles: u64,
    /// Completed decode (GenAI) requests; 0 in single-shot traces.
    pub decode_requests: u64,
    /// Tokens generated across all completions: `decode_tokens` per
    /// decode request, 1 per single-shot inference.
    pub tokens_generated: u64,
    /// Median time-to-first-token over completed decode requests,
    /// milliseconds (arrival → prefill finish; 0 without decode
    /// requests).
    pub ttft_p50_ms: f64,
    /// 99th-percentile time-to-first-token, milliseconds.
    pub ttft_p99_ms: f64,
    /// Mean time-per-output-token over completions that generated at
    /// least 2 tokens, milliseconds: decode-phase span divided by
    /// `tokens − 1`, averaged per request.
    pub tpot_mean_ms: f64,
    /// Generation throughput: tokens generated per second of makespan.
    pub tokens_per_s: f64,
    /// KV-cache residency entries evicted by other installs (capacity
    /// preemption; each forces the victim sequence to re-pay its cache
    /// stream).
    pub kv_evictions: u64,
    /// Total energy metered over the run, femtojoules: the sum of every
    /// completion's attributed energy plus the fleet's inter-dispatch
    /// idle energy (instances waiting between requests still leak and
    /// pay idle floors up to the makespan). Exactly
    /// `energy_compute_fj + energy_dma_fj + energy_idle_fj` — the
    /// conservation invariant, held in integer femtojoules. 0 when
    /// energy accounting is off.
    pub energy_total_fj: u64,
    /// Energy attributed to compute (PE array + TCM banks active), fJ.
    pub energy_compute_fj: u64,
    /// Energy attributed to counted DMA transfers, fJ.
    pub energy_dma_fj: u64,
    /// Energy attributed to idle floors and leakage — including the
    /// inter-dispatch gaps instances spent waiting — fJ.
    pub energy_idle_fj: u64,
    /// Mean metered energy per completed request, joules (0 when energy
    /// accounting is off or nothing completed).
    pub joules_per_inference: f64,
    /// Mean metered energy per generated token over decode completions
    /// only, joules (0 without decode requests or with energy off).
    pub joules_per_token: f64,
    /// Per-model statistics, in the caller's model order.
    pub per_model: Vec<ModelStats>,
    /// Per-priority-class statistics, highest class first (always all
    /// three classes, so reports stay structurally comparable).
    pub per_class: Vec<ClassStats>,
    /// Cycles each instance spent occupied, indexed by instance id.
    pub per_instance_busy_cycles: Vec<u64>,
}

impl ServeReport {
    /// Fraction of compile-cache lookups served without running the CP
    /// solver (0 when no lookups happened).
    pub fn cache_hit_rate(&self) -> f64 {
        let total = self.cache_hits + self.cache_misses;
        if total == 0 {
            0.0
        } else {
            self.cache_hits as f64 / total as f64
        }
    }

    /// Fraction of offered requests shed by admission control (0 when
    /// nothing was offered).
    pub fn shed_rate(&self) -> f64 {
        if self.offered == 0 {
            0.0
        } else {
            self.shed as f64 / self.offered as f64
        }
    }

    /// Fraction of parameter-tile residency lookups that hit TCM (0 when
    /// weight residency was off or nothing was looked up).
    pub fn residency_hit_rate(&self) -> f64 {
        let total = self.residency_hits + self.residency_misses;
        if total == 0 {
            0.0
        } else {
            self.residency_hits as f64 / total as f64
        }
    }

    /// Mean fraction of the makespan the virtual instances spent busy.
    pub fn utilization(&self) -> f64 {
        if self.makespan_cycles == 0 || self.per_instance_busy_cycles.is_empty() {
            return 0.0;
        }
        let busy: u64 = self.per_instance_busy_cycles.iter().sum();
        busy as f64 / (self.makespan_cycles as f64 * self.per_instance_busy_cycles.len() as f64)
    }

    /// Multi-line human-readable report.
    pub fn summary(&self) -> String {
        use std::fmt::Write;
        let mut s = String::new();
        writeln!(
            s,
            "offered:      {} requests over {} virtual NPU instance(s), {} model(s)",
            self.offered,
            self.instances,
            self.per_model.len()
        )
        .unwrap();
        writeln!(
            s,
            "admission:    {} served, {} shed ({:.1}% of offered load {:.1} inf/s)",
            self.completed,
            self.shed,
            self.shed_rate() * 100.0,
            self.offered_load_inf_s
        )
        .unwrap();
        writeln!(
            s,
            "makespan:     {:.2} ms  →  goodput {:.1} inf/s",
            cycles_to_ms(self.makespan_cycles as f64, self.freq_ghz),
            self.goodput_inf_s
        )
        .unwrap();
        writeln!(
            s,
            "latency:      p50 {:.3} ms  p95 {:.3} ms  p99 {:.3} ms  p99.9 {:.3} ms  \
             (mean {:.3} ms, queue {:.3} ms)",
            self.p50_ms,
            self.p95_ms,
            self.p99_ms,
            self.p999_ms,
            self.mean_latency_ms,
            self.mean_queue_ms
        )
        .unwrap();
        writeln!(
            s,
            "batching:     {} batches coalesced {} follower request(s)",
            self.batches, self.batched_requests
        )
        .unwrap();
        for c in &self.per_class {
            writeln!(
                s,
                "  class {:<9} {:>5} done {:>5} shed  mean {:>8.3} ms  p99 {:>8.3} ms  \
                 p99.9 {:>8.3} ms",
                c.priority.display_name(),
                c.completed,
                c.shed,
                c.mean_latency_ms,
                c.p99_ms,
                c.p999_ms
            )
            .unwrap();
        }
        if self.decode_requests > 0 {
            writeln!(
                s,
                "genai:        {} decode request(s), {} token(s) at {:.1} tok/s  \
                 TTFT p50 {:.3} ms p99 {:.3} ms  TPOT mean {:.3} ms  {} KV eviction(s)",
                self.decode_requests,
                self.tokens_generated,
                self.tokens_per_s,
                self.ttft_p50_ms,
                self.ttft_p99_ms,
                self.tpot_mean_ms,
                self.kv_evictions
            )
            .unwrap();
        }
        if self.energy_total_fj > 0 {
            write!(
                s,
                "energy:       {:.6} J total ({:.1}% compute, {:.1}% dma, {:.1}% idle)  \
                 {:.6} J/inf",
                fj_to_joules(self.energy_total_fj),
                self.energy_compute_fj as f64 / self.energy_total_fj as f64 * 100.0,
                self.energy_dma_fj as f64 / self.energy_total_fj as f64 * 100.0,
                self.energy_idle_fj as f64 / self.energy_total_fj as f64 * 100.0,
                self.joules_per_inference
            )
            .unwrap();
            if self.decode_requests > 0 {
                write!(s, "  {:.9} J/tok", self.joules_per_token).unwrap();
            }
            writeln!(s).unwrap();
        }
        writeln!(
            s,
            "pipelining:   {} overlap cycle(s) hidden in fetch-free tails",
            self.overlap_cycles
        )
        .unwrap();
        writeln!(
            s,
            "residency:    {} hits / {} misses ({:.1}% hit rate), {} eviction(s), \
             {} warm dispatch(es)",
            self.residency_hits,
            self.residency_misses,
            self.residency_hit_rate() * 100.0,
            self.residency_evictions,
            self.warm_dispatches
        )
        .unwrap();
        writeln!(
            s,
            "compile cache: {} hits / {} misses ({:.1}% hit rate)",
            self.cache_hits,
            self.cache_misses,
            self.cache_hit_rate() * 100.0
        )
        .unwrap();
        writeln!(s, "utilization:  {:.1}% mean across instances", self.utilization() * 100.0)
            .unwrap();
        for m in &self.per_model {
            let share = if self.makespan_cycles == 0 || self.instances == 0 {
                0.0
            } else {
                m.busy_cycles as f64
                    / (self.makespan_cycles as f64 * self.instances as f64)
                    * 100.0
            };
            writeln!(
                s,
                "  {:<20} {:>5} req  util {:>5.1}%  mean latency {:>8.3} ms",
                m.model.display_name(),
                m.requests,
                share,
                m.mean_latency_ms
            )
            .unwrap();
        }
        s
    }
}

fn cycles_to_ms(cycles: f64, freq_ghz: f64) -> f64 {
    cycles / (freq_ghz * 1e9) * 1e3
}

/// Nearest-rank percentile over an ascending-sorted slice (0 when empty).
fn percentile(sorted: &[u64], q: f64) -> u64 {
    if sorted.is_empty() {
        return 0;
    }
    let idx = ((sorted.len() - 1) as f64 * q).round() as usize;
    sorted[idx.min(sorted.len() - 1)]
}

/// Cycles each completion kept its instance occupied: the full service
/// for a batch leader or solo request, only the marginal tail for a batch
/// follower. Relies on batches being contiguous in `completions` (see
/// [`TraceOutcome`]).
fn occupancy_cycles(completions: &[Completion]) -> Vec<u64> {
    completions
        .iter()
        .enumerate()
        .map(|(i, c)| {
            if c.batch_index == 0 {
                c.finish_cycles - c.start_cycles
            } else {
                c.finish_cycles - completions[i - 1].finish_cycles
            }
        })
        .collect()
}

/// Run a prepared `trace` (arrivals must be non-decreasing) through the
/// scheduler, resolving each dispatch's program through `cache`.
///
/// Event order is deterministic: before each arrival is admitted, every
/// dispatch whose start time is ≤ the arrival's timestamp runs first
/// ("service precedes admission at equal times"); after the last arrival
/// the queue drains completely.
pub fn run_trace(
    cfg: &NeutronConfig,
    trace: &[Request],
    scheduler_opts: &SchedulerOptions,
    cache: &mut CompileCache,
) -> TraceOutcome {
    run_trace_recorded(cfg, trace, scheduler_opts, cache, None)
}

/// [`run_trace`] with an optional [`TraceRecorder`] hooked into the event
/// loop: every offered request is recorded at admission time, every
/// dispatched model's per-op tick profile is captured the first time its
/// cached program is resolved, and the outcome (completions + shed set)
/// is folded in at the end. Recording observes the run — it never changes
/// a scheduling decision, so a recorded run's `TraceOutcome` is identical
/// to an unrecorded one.
pub fn run_trace_recorded(
    cfg: &NeutronConfig,
    trace: &[Request],
    scheduler_opts: &SchedulerOptions,
    cache: &mut CompileCache,
    mut recorder: Option<&mut TraceRecorder>,
) -> TraceOutcome {
    assert!(
        trace.windows(2).all(|w| w[0].arrival_cycles <= w[1].arrival_cycles),
        "trace arrivals must be non-decreasing"
    );
    let mut scheduler = Scheduler::new(cfg, scheduler_opts);
    // Resolve the decode-bucket ladder for every decode-capable model the
    // trace touches before any event runs. The ladder covers the largest
    // context the trace actually reaches (prompt + generated tokens), in
    // first-occurrence order so compile order — and therefore the recorded
    // trace bytes — stays deterministic.
    let mut decode_models: Vec<(ModelId, u32)> = Vec::new();
    for r in trace.iter().filter(|r| r.is_decode()) {
        let need = r.prompt_tokens.saturating_add(r.decode_tokens);
        match decode_models.iter_mut().find(|(m, _)| *m == r.model) {
            Some((_, max_ctx)) => *max_ctx = (*max_ctx).max(need),
            None => decode_models.push((r.model, need)),
        }
    }
    for &(model, max_ctx) in &decode_models {
        let job = cache.get_decode(model, max_ctx);
        if let Some(rec) = recorder.as_deref_mut() {
            let entry = cache.get(model);
            rec.record_model_profile(cfg, &entry);
        }
        scheduler.register_decode_job(model, job);
    }
    let mut completions = Vec::with_capacity(trace.len());
    for &request in trace {
        run_due_events(
            cfg,
            &mut scheduler,
            cache,
            &mut recorder,
            &mut completions,
            request.arrival_cycles,
        );
        if let Some(rec) = recorder.as_deref_mut() {
            rec.record_request(&request);
        }
        scheduler.admit(request);
    }
    run_due_events(cfg, &mut scheduler, cache, &mut recorder, &mut completions, u64::MAX);
    let outcome = TraceOutcome {
        completions,
        shed: scheduler.shed().to_vec(),
        per_instance_busy_cycles: scheduler.instances().iter().map(|i| i.busy_cycles()).collect(),
        residency_hits: scheduler.residency_hits(),
        residency_misses: scheduler.residency_misses(),
        residency_evictions: scheduler.residency_evictions(),
        warm_dispatches: scheduler.warm_dispatches(),
        overlap_cycles: scheduler.overlap_cycles(),
        kv_evictions: scheduler.kv_evictions(),
        tokens_generated: scheduler.tokens_generated(),
    };
    if let Some(rec) = recorder {
        rec.record_outcome(&outcome);
    }
    outcome
}

/// Run every service event due at or before `horizon_cycles`: decode
/// rounds (continuous batching) and queue dispatches, whichever starts
/// earlier, with decode rounds winning ties so in-flight sequences make
/// progress before new work lands on their instance. Called with an
/// arrival's timestamp between admissions and with `u64::MAX` to drain.
fn run_due_events(
    cfg: &NeutronConfig,
    scheduler: &mut Scheduler,
    cache: &mut CompileCache,
    recorder: &mut Option<&mut TraceRecorder>,
    completions: &mut Vec<Completion>,
    horizon_cycles: u64,
) {
    loop {
        let round = scheduler.next_decode_round_start().filter(|&t| t <= horizon_cycles);
        let dispatch = scheduler.next_start_cycles().filter(|&t| t <= horizon_cycles);
        match (round, dispatch) {
            (None, None) => break,
            (Some(r), d) if d.map_or(true, |d| r <= d) => {
                if let Some(batch) = scheduler.advance_decode(horizon_cycles) {
                    completions.extend(batch);
                }
            }
            _ => {
                let model = scheduler
                    .next_model_before(horizon_cycles)
                    .expect("a dispatch due by the horizon must resolve a model");
                let entry = cache.get(model);
                if let Some(rec) = recorder.as_deref_mut() {
                    rec.record_model_profile(cfg, &entry);
                }
                completions.extend(scheduler.dispatch_next(model, &entry.program));
            }
        }
    }
}

/// Serve a synthetic multi-tenant trace with a caller-owned cache (reuse
/// the cache across calls to keep compiles warm).
pub fn serve_with_cache(
    cfg: &NeutronConfig,
    opts: &ServeOptions,
    cache: &mut CompileCache,
) -> ServeReport {
    serve_with_cache_recorded(cfg, opts, cache, None)
}

/// [`serve_with_cache`] with an optional [`TraceRecorder`] observing the
/// run. Recorded and unrecorded serving share this single code path, so
/// "recording never changes the run" holds by construction; the trace
/// capture front-end (`trace::serve_recorded`) wraps this with a
/// recorder and returns the finished trace alongside the report.
pub fn serve_with_cache_recorded(
    cfg: &NeutronConfig,
    opts: &ServeOptions,
    cache: &mut CompileCache,
    recorder: Option<&mut TraceRecorder>,
) -> ServeReport {
    assert!(!opts.models.is_empty(), "serving needs at least one model");
    let (hits0, misses0) = (cache.hits, cache.misses);
    let trace = if opts.decode {
        assert!(opts.prompt_tokens >= 1, "decode serving needs a prompt of at least 1 token");
        assert!(opts.decode_tokens >= 1, "decode serving generates at least 1 token");
        assert!(
            opts.prompt_tokens.saturating_add(opts.decode_tokens) <= opts.max_context,
            "prompt_tokens ({}) + decode_tokens ({}) exceeds max_context ({})",
            opts.prompt_tokens,
            opts.decode_tokens,
            opts.max_context
        );
        for &model in &opts.models {
            assert!(
                model.decode_config().is_some(),
                "model {} has no decode configuration (decode serving needs autoregressive \
                 models)",
                model.slug()
            );
        }
        synthetic_decode_trace(
            &opts.models,
            opts.requests,
            opts.mean_gap_cycles,
            opts.seed,
            opts.prompt_tokens,
            opts.decode_tokens,
        )
    } else {
        synthetic_trace_with_mix(
            &opts.models,
            opts.requests,
            opts.mean_gap_cycles,
            opts.seed,
            &opts.priority_mix,
        )
    };
    let outcome = run_trace_recorded(cfg, &trace, &opts.scheduler, cache, recorder);
    report_from_outcome(
        cfg,
        &opts.models,
        opts.scheduler.instances,
        &trace,
        &outcome,
        cache.hits - hits0,
        cache.misses - misses0,
    )
}

/// Serve with a fresh deterministic cache.
pub fn serve(cfg: &NeutronConfig, opts: &ServeOptions) -> ServeReport {
    let mut cache = CompileCache::for_serving(cfg.clone());
    serve_with_cache(cfg, opts, &mut cache)
}

/// Fold a [`TraceOutcome`] into a [`ServeReport`]. `models` fixes the
/// per-model row order (duplicates collapse onto their first occurrence);
/// `instances` is the fleet size the outcome ran on. Public so the trace
/// replay driver builds reports through exactly the same code path as
/// [`serve`] — bit-identical replay depends on there being one report
/// builder.
pub fn report_from_outcome(
    cfg: &NeutronConfig,
    models: &[ModelId],
    instances: usize,
    trace: &[Request],
    outcome: &TraceOutcome,
    cache_hits: u64,
    cache_misses: u64,
) -> ServeReport {
    let freq = cfg.freq_ghz;
    let completions = &outcome.completions;
    let n = completions.len() as u64;
    let occupancy = occupancy_cycles(completions);
    let mut latencies: Vec<u64> = completions.iter().map(|c| c.latency_cycles()).collect();
    latencies.sort_unstable();
    let makespan = completions.iter().map(|c| c.finish_cycles).max().unwrap_or(0);
    let goodput = if makespan == 0 {
        0.0
    } else {
        n as f64 * freq * 1e9 / makespan as f64
    };
    let arrival_span = trace.last().map(|r| r.arrival_cycles).unwrap_or(0);
    let offered_load = if arrival_span == 0 {
        0.0
    } else {
        trace.len() as f64 * freq * 1e9 / arrival_span as f64
    };
    let mean_latency_cycles = if n == 0 {
        0.0
    } else {
        latencies.iter().sum::<u64>() as f64 / n as f64
    };
    let mean_queue_cycles = if n == 0 {
        0.0
    } else {
        completions.iter().map(|c| c.queue_cycles()).sum::<u64>() as f64 / n as f64
    };
    let batched_requests = completions.iter().filter(|c| c.batch_index > 0).count() as u64;
    let batches = completions.iter().filter(|c| c.batch_index == 1).count() as u64;

    // Token metrics. TTFT percentiles cover decode requests only (a
    // single-shot request's "first token" is just its latency and would
    // pollute the distribution); TPOT averages over completions that
    // actually decoded (tokens ≥ 2).
    let decode_ids: std::collections::HashSet<u64> =
        trace.iter().filter(|r| r.is_decode()).map(|r| r.id).collect();
    let mut ttfts: Vec<u64> = completions
        .iter()
        .filter(|c| decode_ids.contains(&c.id))
        .map(|c| c.ttft_cycles())
        .collect();
    ttfts.sort_unstable();
    let decode_requests = ttfts.len() as u64;
    let tpots: Vec<f64> = completions.iter().filter_map(|c| c.tpot_cycles()).collect();
    let tpot_mean_cycles = if tpots.is_empty() {
        0.0
    } else {
        tpots.iter().sum::<f64>() / tpots.len() as f64
    };
    let tokens_per_s = if makespan == 0 {
        0.0
    } else {
        outcome.tokens_generated as f64 * freq * 1e9 / makespan as f64
    };

    // Energy. Whether the run was metered is read off the completions
    // themselves — the leakage floor prices every non-empty service
    // above 0 fJ — so replayed traces fold energy through this same
    // builder with no extra plumbing. The scheduler attributes energy
    // to requests; the inter-dispatch gaps (instances waiting between
    // requests still leak and pay idle floors) are priced here, because
    // only the report sees the fleet-wide makespan.
    let mut energy_compute_fj: u64 = 0;
    let mut energy_dma_fj: u64 = 0;
    let mut energy_idle_fj: u64 = 0;
    for c in completions {
        energy_compute_fj = energy_compute_fj.saturating_add(c.energy_compute_fj);
        energy_dma_fj = energy_dma_fj.saturating_add(c.energy_dma_fj);
        energy_idle_fj = energy_idle_fj.saturating_add(c.energy_idle_fj);
    }
    let energy_on = energy_compute_fj > 0 || energy_dma_fj > 0 || energy_idle_fj > 0;
    if energy_on {
        let model = EnergyModel::for_config(cfg);
        for &busy in &outcome.per_instance_busy_cycles {
            let gap = makespan.saturating_sub(busy);
            energy_idle_fj = energy_idle_fj.saturating_add(model.price_tick(gap, 0, 0).total_fj());
        }
    }
    let energy_total_fj = energy_compute_fj
        .saturating_add(energy_dma_fj)
        .saturating_add(energy_idle_fj);
    let joules_per_inference = if n == 0 {
        0.0
    } else {
        fj_to_joules(energy_total_fj) / n as f64
    };
    let decode_energy_fj: u64 = completions
        .iter()
        .filter(|c| decode_ids.contains(&c.id))
        .map(|c| c.energy_total_fj())
        .sum();
    let decode_token_count: u64 = completions
        .iter()
        .filter(|c| decode_ids.contains(&c.id))
        .map(|c| c.tokens as u64)
        .sum();
    let joules_per_token = if decode_token_count == 0 {
        0.0
    } else {
        fj_to_joules(decode_energy_fj) / decode_token_count as f64
    };

    // Per-model stats in the caller's model order (first occurrence wins,
    // so duplicate entries in `models` stay deterministic).
    let mut per_model = Vec::new();
    let mut seen: Vec<ModelId> = Vec::new();
    for &model in models {
        if seen.contains(&model) {
            continue;
        }
        seen.push(model);
        let mut requests = 0u64;
        let mut busy = 0u64;
        let mut latency_sum = 0u64;
        for (c, &occ) in completions.iter().zip(&occupancy) {
            if c.model == model {
                requests += 1;
                busy += occ;
                latency_sum += c.latency_cycles();
            }
        }
        per_model.push(ModelStats {
            model,
            requests,
            busy_cycles: busy,
            mean_latency_ms: if requests == 0 {
                0.0
            } else {
                cycles_to_ms(latency_sum as f64 / requests as f64, freq)
            },
        });
    }

    let per_class = Priority::all()
        .into_iter()
        .map(|priority| {
            let mut lat: Vec<u64> = completions
                .iter()
                .filter(|c| c.priority == priority)
                .map(|c| c.latency_cycles())
                .collect();
            lat.sort_unstable();
            let completed = lat.len() as u64;
            let shed = outcome.shed.iter().filter(|r| r.priority == priority).count() as u64;
            ClassStats {
                priority,
                completed,
                shed,
                mean_latency_ms: if completed == 0 {
                    0.0
                } else {
                    cycles_to_ms(lat.iter().sum::<u64>() as f64 / completed as f64, freq)
                },
                p99_ms: cycles_to_ms(percentile(&lat, 0.99) as f64, freq),
                p999_ms: cycles_to_ms(percentile(&lat, 0.999) as f64, freq),
            }
        })
        .collect();

    ServeReport {
        offered: trace.len() as u64,
        completed: n,
        shed: outcome.shed.len() as u64,
        instances,
        freq_ghz: freq,
        makespan_cycles: makespan,
        offered_load_inf_s: offered_load,
        goodput_inf_s: goodput,
        mean_latency_ms: cycles_to_ms(mean_latency_cycles, freq),
        p50_ms: cycles_to_ms(percentile(&latencies, 0.50) as f64, freq),
        p95_ms: cycles_to_ms(percentile(&latencies, 0.95) as f64, freq),
        p99_ms: cycles_to_ms(percentile(&latencies, 0.99) as f64, freq),
        p999_ms: cycles_to_ms(percentile(&latencies, 0.999) as f64, freq),
        mean_queue_ms: cycles_to_ms(mean_queue_cycles, freq),
        batches,
        batched_requests,
        cache_hits,
        cache_misses,
        residency_hits: outcome.residency_hits,
        residency_misses: outcome.residency_misses,
        residency_evictions: outcome.residency_evictions,
        warm_dispatches: outcome.warm_dispatches,
        overlap_cycles: outcome.overlap_cycles,
        decode_requests,
        tokens_generated: outcome.tokens_generated,
        ttft_p50_ms: cycles_to_ms(percentile(&ttfts, 0.50) as f64, freq),
        ttft_p99_ms: cycles_to_ms(percentile(&ttfts, 0.99) as f64, freq),
        tpot_mean_ms: cycles_to_ms(tpot_mean_cycles, freq),
        tokens_per_s,
        kv_evictions: outcome.kv_evictions,
        energy_total_fj,
        energy_compute_fj,
        energy_dma_fj,
        energy_idle_fj,
        joules_per_inference,
        joules_per_token,
        per_model,
        per_class,
        per_instance_busy_cycles: outcome.per_instance_busy_cycles.clone(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::queue::AdmissionPolicy;

    #[test]
    fn percentile_nearest_rank() {
        assert_eq!(percentile(&[], 0.5), 0);
        let v: Vec<u64> = (1..=100).collect();
        assert_eq!(percentile(&v, 0.0), 1);
        assert_eq!(percentile(&v, 1.0), 100);
        assert_eq!(percentile(&v, 0.5), 51); // round(99·0.5) = 50 → v[50]
        assert_eq!(percentile(&[7], 0.99), 7);
    }

    #[test]
    fn small_serve_is_conserving_and_warm_reruns_match() {
        let cfg = NeutronConfig::flagship_2tops();
        let opts = ServeOptions {
            models: vec![ModelId::MobileNetV3Min, ModelId::MobileNetV1],
            requests: 24,
            mean_gap_cycles: 400_000,
            seed: 11,
            scheduler: SchedulerOptions { instances: 2, ..SchedulerOptions::default() },
            ..ServeOptions::default()
        };
        let mut cache = CompileCache::for_serving(cfg.clone());
        let a = serve_with_cache(&cfg, &opts, &mut cache);
        assert_eq!(a.offered, 24);
        assert_eq!(a.completed, 24);
        assert_eq!(a.shed, 0, "unbounded queue never sheds");
        assert_eq!(a.shed_rate(), 0.0);
        assert_eq!(a.cache_misses, 2);
        assert_eq!(a.cache_hits, 22);
        assert!(a.cache_hit_rate() > 0.9);
        assert!(a.p50_ms > 0.0);
        assert!(a.p50_ms <= a.p95_ms && a.p95_ms <= a.p99_ms && a.p99_ms <= a.p999_ms);
        assert!(a.utilization() > 0.0 && a.utilization() <= 1.0);
        assert!(a.offered_load_inf_s > 0.0);
        assert_eq!(a.per_model.iter().map(|m| m.requests).sum::<u64>(), 24);
        assert_eq!(a.per_class.iter().map(|c| c.completed).sum::<u64>(), 24);
        assert_eq!(a.per_class.len(), 3);
        assert_eq!(a.per_instance_busy_cycles.len(), 2);

        // Warm rerun: identical virtual-clock timing, all cache hits.
        let b = serve_with_cache(&cfg, &opts, &mut cache);
        assert_eq!(b.cache_misses, 0);
        assert_eq!(b.cache_hits, 24);
        assert_eq!(
            (a.makespan_cycles, a.p50_ms, a.p95_ms, a.p99_ms, a.goodput_inf_s),
            (b.makespan_cycles, b.p50_ms, b.p95_ms, b.p99_ms, b.goodput_inf_s)
        );
        assert_eq!(a.per_model, b.per_model);
        assert_eq!(a.per_class, b.per_class);
    }

    #[test]
    fn bounded_queue_sheds_under_overload_and_bounds_queueing() {
        let cfg = NeutronConfig::flagship_2tops();
        // Near-simultaneous arrivals of one model over one instance: the
        // queue cannot keep up, so a bounded queue must shed.
        let base = ServeOptions {
            models: vec![ModelId::MobileNetV3Min],
            requests: 40,
            mean_gap_cycles: 1_000,
            seed: 3,
            priority_mix: PriorityMix::standard_only(),
            scheduler: SchedulerOptions { instances: 1, ..SchedulerOptions::default() },
            ..ServeOptions::default()
        };
        let mut cache = CompileCache::for_serving(cfg.clone());
        let unbounded = serve_with_cache(&cfg, &base, &mut cache);
        assert_eq!(unbounded.shed, 0);
        assert_eq!(unbounded.completed, 40);

        let bounded = ServeOptions {
            scheduler: SchedulerOptions {
                instances: 1,
                queue_capacity: Some(4),
                policy: AdmissionPolicy::RejectNewest,
                ..SchedulerOptions::default()
            },
            ..base.clone()
        };
        let r = serve_with_cache(&cfg, &bounded, &mut cache);
        assert_eq!(r.offered, 40);
        assert_eq!(r.completed + r.shed, 40, "offered = served + shed");
        assert!(r.shed > 0, "sustained overload must shed with a bounded queue");
        assert!(r.shed_rate() > 0.0);
        // Shedding bounds the backlog, so tail latency improves on the
        // unbounded run.
        assert!(r.p99_ms < unbounded.p99_ms);
        assert!(r.makespan_cycles <= unbounded.makespan_cycles);
        let s = r.summary();
        assert!(s.contains("shed") && s.contains("goodput"));
    }

    #[test]
    fn decode_serve_reports_token_metrics_and_is_deterministic() {
        let cfg = NeutronConfig::flagship_2tops();
        let opts = ServeOptions {
            models: vec![ModelId::GptTiny],
            requests: 6,
            mean_gap_cycles: 200_000,
            seed: 5,
            scheduler: SchedulerOptions { instances: 1, ..SchedulerOptions::default() },
            decode: true,
            prompt_tokens: 6,
            decode_tokens: 5,
            max_context: 16,
            ..ServeOptions::default()
        };
        let a = serve(&cfg, &opts);
        assert_eq!(a.offered, 6);
        assert_eq!(a.completed, 6);
        assert_eq!(a.decode_requests, 6);
        assert_eq!(a.tokens_generated, 6 * 5);
        assert!(a.tokens_per_s > 0.0);
        assert!(a.ttft_p50_ms > 0.0);
        assert!(a.ttft_p50_ms <= a.ttft_p99_ms);
        // Per-request TTFT ≤ latency, so the sorted distributions dominate
        // pointwise and every TTFT percentile bounds its latency peer.
        assert!(a.ttft_p99_ms <= a.p99_ms);
        assert!(a.tpot_mean_ms > 0.0);
        assert!(a.summary().contains("genai:"));

        // Same options, fresh cache: bit-identical report.
        let b = serve(&cfg, &opts);
        assert_eq!(a, b);
    }

    #[test]
    fn continuous_batching_improves_decode_makespan_and_tpot() {
        let cfg = NeutronConfig::flagship_2tops();
        let base = ServeOptions {
            models: vec![ModelId::GptTiny],
            requests: 8,
            mean_gap_cycles: 50_000,
            seed: 9,
            scheduler: SchedulerOptions { instances: 1, ..SchedulerOptions::default() },
            decode: true,
            prompt_tokens: 4,
            decode_tokens: 6,
            max_context: 16,
            ..ServeOptions::default()
        };
        let mut cache = CompileCache::for_serving(cfg.clone());
        let rb = serve_with_cache(&cfg, &base, &mut cache);
        let cont = ServeOptions {
            scheduler: SchedulerOptions {
                instances: 1,
                continuous_batch: true,
                ..SchedulerOptions::default()
            },
            ..base.clone()
        };
        let cb = serve_with_cache(&cfg, &cont, &mut cache);
        assert_eq!(cb.completed, rb.completed);
        assert_eq!(cb.tokens_generated, rb.tokens_generated);
        // Pinned decode weights elide per-step parameter streaming, so
        // continuous batching strictly beats request-boundary replay on
        // both throughput and per-token latency.
        assert!(cb.makespan_cycles < rb.makespan_cycles);
        assert!(cb.tpot_mean_ms < rb.tpot_mean_ms);
        // Earlier finishes free the instance sooner, so queueing — and
        // with it TTFT — never regresses.
        assert!(cb.ttft_p50_ms <= rb.ttft_p50_ms);
    }

    #[test]
    fn zero_requests_are_division_safe() {
        let cfg = NeutronConfig::flagship_2tops();
        let opts = ServeOptions {
            models: vec![ModelId::MobileNetV3Min],
            requests: 0,
            mean_gap_cycles: 0,
            seed: 1,
            scheduler: SchedulerOptions { instances: 1, ..SchedulerOptions::default() },
            ..ServeOptions::default()
        };
        let r = serve(&cfg, &opts);
        assert_eq!(r.offered, 0);
        assert_eq!(r.completed, 0);
        assert_eq!(r.shed, 0);
        assert_eq!(r.goodput_inf_s, 0.0);
        assert_eq!(r.offered_load_inf_s, 0.0);
        assert_eq!(r.p99_ms, 0.0);
        assert_eq!(r.p999_ms, 0.0);
        assert_eq!(r.mean_latency_ms, 0.0);
        assert_eq!(r.utilization(), 0.0);
        assert_eq!(r.cache_hit_rate(), 0.0);
        assert_eq!(r.shed_rate(), 0.0);
        assert!(r.summary().contains("offered"));
        assert_eq!(r.energy_total_fj, 0);
        assert_eq!(r.joules_per_inference, 0.0);
    }

    #[test]
    fn energy_report_conserves_and_is_invisible_when_off() {
        let cfg = NeutronConfig::flagship_2tops();
        let base = ServeOptions {
            models: vec![ModelId::MobileNetV3Min, ModelId::MobileNetV1],
            requests: 16,
            mean_gap_cycles: 500_000,
            seed: 13,
            scheduler: SchedulerOptions { instances: 2, ..SchedulerOptions::default() },
            ..ServeOptions::default()
        };
        let off = serve(&cfg, &base);
        assert_eq!(off.energy_total_fj, 0);
        assert_eq!(off.joules_per_inference, 0.0);
        assert!(!off.summary().contains("energy:"), "off-run summaries show no energy line");

        let on_opts = ServeOptions {
            scheduler: SchedulerOptions {
                instances: 2,
                energy: true,
                ..SchedulerOptions::default()
            },
            ..base.clone()
        };
        let on = serve(&cfg, &on_opts);
        // The meter never moves the clock: every timing field matches.
        assert_eq!(off.makespan_cycles, on.makespan_cycles);
        assert_eq!(
            (off.p50_ms, off.p99_ms, off.goodput_inf_s, off.mean_queue_ms),
            (on.p50_ms, on.p99_ms, on.goodput_inf_s, on.mean_queue_ms)
        );
        assert_eq!(off.per_model, on.per_model);
        // Conservation is exact in integer femtojoules.
        assert!(on.energy_total_fj > 0);
        assert_eq!(
            on.energy_compute_fj + on.energy_dma_fj + on.energy_idle_fj,
            on.energy_total_fj
        );
        assert!(on.joules_per_inference > 0.0);
        assert_eq!(on.joules_per_token, 0.0, "no decode requests, no per-token figure");
        assert!(on.summary().contains("energy:"));
        assert!(on.summary().contains("J/inf"));
        // Determinism: rerun is bit-identical, energy included.
        let again = serve(&cfg, &on_opts);
        assert_eq!(on, again);
    }

    #[test]
    fn decode_energy_report_prices_tokens() {
        let cfg = NeutronConfig::flagship_2tops();
        let opts = ServeOptions {
            models: vec![ModelId::GptTiny],
            requests: 4,
            mean_gap_cycles: 200_000,
            seed: 5,
            scheduler: SchedulerOptions {
                instances: 1,
                energy: true,
                ..SchedulerOptions::default()
            },
            decode: true,
            prompt_tokens: 6,
            decode_tokens: 5,
            max_context: 16,
            ..ServeOptions::default()
        };
        let r = serve(&cfg, &opts);
        assert_eq!(r.decode_requests, 4);
        assert!(r.energy_total_fj > 0);
        assert!(r.joules_per_token > 0.0);
        assert!(
            r.joules_per_token < r.joules_per_inference,
            "a token is a fraction of a multi-token inference"
        );
        assert!(r.summary().contains("J/tok"));
    }
}
