//! Serving front-end: synthetic trace → compile cache → scheduler →
//! [`ServeReport`].

use crate::arch::NeutronConfig;
use crate::zoo::ModelId;

use super::cache::CompileCache;
use super::queue::{synthetic_trace, Completion, Request, Scheduler};

/// Serving scenario parameters.
#[derive(Debug, Clone)]
pub struct ServeOptions {
    /// Tenant model mix (requests draw uniformly from this list).
    pub models: Vec<ModelId>,
    pub requests: usize,
    /// Virtual NPU instances sharing the admission queue.
    pub instances: usize,
    /// Mean inter-arrival gap on the virtual clock, cycles.
    pub mean_gap_cycles: u64,
    pub seed: u64,
}

impl Default for ServeOptions {
    fn default() -> Self {
        Self {
            models: vec![
                ModelId::MobileNetV2,
                ModelId::MobileNetV1,
                ModelId::EfficientNetLite0,
            ],
            requests: 200,
            instances: 2,
            // ~0.6 ms at 1 GHz: keeps two instances around 80% busy on
            // the ~1 ms default model mix.
            mean_gap_cycles: 600_000,
            seed: 7,
        }
    }
}

/// Per-model serving statistics.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelStats {
    pub model: ModelId,
    pub requests: u64,
    /// Cycles this model kept instances busy (utilization numerator).
    pub busy_cycles: u64,
    pub mean_latency_ms: f64,
}

/// Aggregate serving report. Fully determined by `(config, options)`: no
/// wall-clock value enters any field, so two runs with the same seed
/// compare equal (see the virtual-clock contract in `serve/mod.rs`).
#[derive(Debug, Clone, PartialEq)]
pub struct ServeReport {
    pub requests: u64,
    pub instances: usize,
    pub freq_ghz: f64,
    /// Virtual-clock cycle when the last request finished.
    pub makespan_cycles: u64,
    pub throughput_inf_s: f64,
    pub mean_latency_ms: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
    pub mean_queue_ms: f64,
    pub cache_hits: u64,
    pub cache_misses: u64,
    pub per_model: Vec<ModelStats>,
    pub per_instance_busy_cycles: Vec<u64>,
}

impl ServeReport {
    /// Fraction of compile-cache lookups served without running the CP
    /// solver (0 when no lookups happened).
    pub fn cache_hit_rate(&self) -> f64 {
        let total = self.cache_hits + self.cache_misses;
        if total == 0 {
            0.0
        } else {
            self.cache_hits as f64 / total as f64
        }
    }

    /// Mean fraction of the makespan the virtual instances spent busy.
    pub fn utilization(&self) -> f64 {
        if self.makespan_cycles == 0 || self.per_instance_busy_cycles.is_empty() {
            return 0.0;
        }
        let busy: u64 = self.per_instance_busy_cycles.iter().sum();
        busy as f64 / (self.makespan_cycles as f64 * self.per_instance_busy_cycles.len() as f64)
    }

    /// Multi-line human-readable report.
    pub fn summary(&self) -> String {
        use std::fmt::Write;
        let mut s = String::new();
        writeln!(
            s,
            "requests:     {} over {} virtual NPU instance(s), {} model(s)",
            self.requests,
            self.instances,
            self.per_model.len()
        )
        .unwrap();
        writeln!(
            s,
            "makespan:     {:.2} ms  →  throughput {:.1} inf/s",
            cycles_to_ms(self.makespan_cycles as f64, self.freq_ghz),
            self.throughput_inf_s
        )
        .unwrap();
        writeln!(
            s,
            "latency:      p50 {:.3} ms  p95 {:.3} ms  p99 {:.3} ms  (mean {:.3} ms, queue {:.3} ms)",
            self.p50_ms, self.p95_ms, self.p99_ms, self.mean_latency_ms, self.mean_queue_ms
        )
        .unwrap();
        writeln!(
            s,
            "compile cache: {} hits / {} misses ({:.1}% hit rate)",
            self.cache_hits,
            self.cache_misses,
            self.cache_hit_rate() * 100.0
        )
        .unwrap();
        writeln!(s, "utilization:  {:.1}% mean across instances", self.utilization() * 100.0)
            .unwrap();
        for m in &self.per_model {
            let share = if self.makespan_cycles == 0 || self.instances == 0 {
                0.0
            } else {
                m.busy_cycles as f64
                    / (self.makespan_cycles as f64 * self.instances as f64)
                    * 100.0
            };
            writeln!(
                s,
                "  {:<20} {:>5} req  util {:>5.1}%  mean latency {:>8.3} ms",
                m.model.display_name(),
                m.requests,
                share,
                m.mean_latency_ms
            )
            .unwrap();
        }
        s
    }
}

fn cycles_to_ms(cycles: f64, freq_ghz: f64) -> f64 {
    cycles / (freq_ghz * 1e9) * 1e3
}

/// Nearest-rank percentile over an ascending-sorted slice (0 when empty).
fn percentile(sorted: &[u64], q: f64) -> u64 {
    if sorted.is_empty() {
        return 0;
    }
    let idx = ((sorted.len() - 1) as f64 * q).round() as usize;
    sorted[idx.min(sorted.len() - 1)]
}

/// Run a prepared `trace` over `instances` virtual NPUs, resolving each
/// request's program through `cache`. Returns the completions in dispatch
/// (= admission) order plus per-instance busy cycles.
pub fn run_trace(
    cfg: &NeutronConfig,
    trace: &[Request],
    instances: usize,
    cache: &mut CompileCache,
) -> (Vec<Completion>, Vec<u64>) {
    let mut scheduler = Scheduler::new(cfg, instances);
    for &request in trace {
        scheduler.admit(request);
    }
    let mut completions = Vec::with_capacity(trace.len());
    while let Some(model) = scheduler.next_model() {
        let entry = cache.get(model);
        if let Some(c) = scheduler.dispatch_next(&entry.program) {
            completions.push(c);
        }
    }
    let busy = scheduler.instances().iter().map(|i| i.busy_cycles()).collect();
    (completions, busy)
}

/// Serve a synthetic multi-tenant trace with a caller-owned cache (reuse
/// the cache across calls to keep compiles warm).
pub fn serve_with_cache(
    cfg: &NeutronConfig,
    opts: &ServeOptions,
    cache: &mut CompileCache,
) -> ServeReport {
    assert!(!opts.models.is_empty(), "serving needs at least one model");
    assert!(opts.instances >= 1, "serving needs at least one instance");
    let (hits0, misses0) = (cache.hits, cache.misses);
    let trace = synthetic_trace(&opts.models, opts.requests, opts.mean_gap_cycles, opts.seed);
    let (completions, per_instance_busy) = run_trace(cfg, &trace, opts.instances, cache);
    build_report(
        cfg,
        opts,
        &completions,
        per_instance_busy,
        cache.hits - hits0,
        cache.misses - misses0,
    )
}

/// Serve with a fresh deterministic cache.
pub fn serve(cfg: &NeutronConfig, opts: &ServeOptions) -> ServeReport {
    let mut cache = CompileCache::for_serving(cfg.clone());
    serve_with_cache(cfg, opts, &mut cache)
}

fn build_report(
    cfg: &NeutronConfig,
    opts: &ServeOptions,
    completions: &[Completion],
    per_instance_busy: Vec<u64>,
    cache_hits: u64,
    cache_misses: u64,
) -> ServeReport {
    let freq = cfg.freq_ghz;
    let n = completions.len() as u64;
    let mut latencies: Vec<u64> = completions.iter().map(|c| c.latency_cycles()).collect();
    latencies.sort_unstable();
    let makespan = completions.iter().map(|c| c.finish_cycles).max().unwrap_or(0);
    let throughput = if makespan == 0 {
        0.0
    } else {
        n as f64 * freq * 1e9 / makespan as f64
    };
    let mean_latency_cycles = if n == 0 {
        0.0
    } else {
        latencies.iter().sum::<u64>() as f64 / n as f64
    };
    let mean_queue_cycles = if n == 0 {
        0.0
    } else {
        completions.iter().map(|c| c.queue_cycles()).sum::<u64>() as f64 / n as f64
    };

    // Per-model stats in the caller's model order (first occurrence wins,
    // so duplicate entries in `models` stay deterministic).
    let mut per_model = Vec::new();
    let mut seen: Vec<ModelId> = Vec::new();
    for &model in &opts.models {
        if seen.contains(&model) {
            continue;
        }
        seen.push(model);
        let mut requests = 0u64;
        let mut busy = 0u64;
        let mut latency_sum = 0u64;
        for c in completions.iter().filter(|c| c.model == model) {
            requests += 1;
            busy += c.service_cycles();
            latency_sum += c.latency_cycles();
        }
        per_model.push(ModelStats {
            model,
            requests,
            busy_cycles: busy,
            mean_latency_ms: if requests == 0 {
                0.0
            } else {
                cycles_to_ms(latency_sum as f64 / requests as f64, freq)
            },
        });
    }

    ServeReport {
        requests: n,
        instances: opts.instances,
        freq_ghz: freq,
        makespan_cycles: makespan,
        throughput_inf_s: throughput,
        mean_latency_ms: cycles_to_ms(mean_latency_cycles, freq),
        p50_ms: cycles_to_ms(percentile(&latencies, 0.50) as f64, freq),
        p95_ms: cycles_to_ms(percentile(&latencies, 0.95) as f64, freq),
        p99_ms: cycles_to_ms(percentile(&latencies, 0.99) as f64, freq),
        mean_queue_ms: cycles_to_ms(mean_queue_cycles, freq),
        cache_hits,
        cache_misses,
        per_model,
        per_instance_busy_cycles: per_instance_busy,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentile_nearest_rank() {
        assert_eq!(percentile(&[], 0.5), 0);
        let v: Vec<u64> = (1..=100).collect();
        assert_eq!(percentile(&v, 0.0), 1);
        assert_eq!(percentile(&v, 1.0), 100);
        assert_eq!(percentile(&v, 0.5), 51); // round(99·0.5) = 50 → v[50]
        assert_eq!(percentile(&[7], 0.99), 7);
    }

    #[test]
    fn small_serve_is_conserving_and_warm_reruns_match() {
        let cfg = NeutronConfig::flagship_2tops();
        let opts = ServeOptions {
            models: vec![ModelId::MobileNetV3Min, ModelId::MobileNetV1],
            requests: 24,
            instances: 2,
            mean_gap_cycles: 400_000,
            seed: 11,
        };
        let mut cache = CompileCache::for_serving(cfg.clone());
        let a = serve_with_cache(&cfg, &opts, &mut cache);
        assert_eq!(a.requests, 24);
        assert_eq!(a.cache_misses, 2);
        assert_eq!(a.cache_hits, 22);
        assert!(a.cache_hit_rate() > 0.9);
        assert!(a.p50_ms > 0.0);
        assert!(a.p50_ms <= a.p95_ms && a.p95_ms <= a.p99_ms);
        assert!(a.utilization() > 0.0 && a.utilization() <= 1.0);
        assert_eq!(a.per_model.iter().map(|m| m.requests).sum::<u64>(), 24);
        assert_eq!(a.per_instance_busy_cycles.len(), 2);

        // Warm rerun: identical virtual-clock timing, all cache hits.
        let b = serve_with_cache(&cfg, &opts, &mut cache);
        assert_eq!(b.cache_misses, 0);
        assert_eq!(b.cache_hits, 24);
        assert_eq!(
            (a.makespan_cycles, a.p50_ms, a.p95_ms, a.p99_ms, a.throughput_inf_s),
            (b.makespan_cycles, b.p50_ms, b.p95_ms, b.p99_ms, b.throughput_inf_s)
        );
        assert_eq!(a.per_model, b.per_model);
    }

    #[test]
    fn zero_requests_are_division_safe() {
        let cfg = NeutronConfig::flagship_2tops();
        let opts = ServeOptions {
            models: vec![ModelId::MobileNetV3Min],
            requests: 0,
            instances: 1,
            mean_gap_cycles: 0,
            seed: 1,
        };
        let r = serve(&cfg, &opts);
        assert_eq!(r.requests, 0);
        assert_eq!(r.throughput_inf_s, 0.0);
        assert_eq!(r.p99_ms, 0.0);
        assert_eq!(r.mean_latency_ms, 0.0);
        assert_eq!(r.utilization(), 0.0);
        assert_eq!(r.cache_hit_rate(), 0.0);
        assert!(r.summary().contains("requests"));
    }
}
