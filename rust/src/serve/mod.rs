//! Multi-tenant serving layer: compile cache + overload-aware scheduler
//! (bounded admission, priority classes, same-model batching) over N
//! virtual NPU instances.
//!
//! The paper's headline claim is *utilization*, not peak TOPS — the stack
//! wins by keeping compute busy. This module turns the single-shot
//! coordinator into a serving simulator for the realistic deployment
//! shape: many models, many tenants, heavy traffic, and sustained
//! overload.
//!
//! Three pieces:
//!
//! * [`CompileCache`] — memoizes `compile` + `emit` per
//!   `(ModelId, NeutronConfig fingerprint, calibration fingerprint)`, so
//!   repeat requests skip the CP solver entirely and calibrated artifacts
//!   coexist with uncalibrated ones;
//! * [`Scheduler`] — a bounded admission queue (overflow shed per
//!   [`AdmissionPolicy`]) feeding a deterministic priority dispatcher
//!   (class first, then admission order, with an optional aging rule
//!   against starvation) over the earliest-idle of N virtual NPU
//!   instances, coalescing same-model same-class requests into batches of
//!   up to [`SchedulerOptions::max_batch`] under backlog — and, opted in
//!   per knob, overlapping a dispatch's head parameter fetches with its
//!   predecessor's fetch-free tail ([`SchedulerOptions::pipeline`]),
//!   keeping hot models' parameter tiles TCM-resident across requests
//!   ([`SchedulerOptions::weight_residency`]) and routing requests to the
//!   instance with the cheapest warm/cold predicted finish
//!   ([`SchedulerOptions::warm_routing`]);
//! * [`serve`] / [`ServeReport`] — runs a synthetic trace and reports
//!   offered load vs. goodput, shed rate, latency percentiles, batching
//!   activity, cache hit rate and utilization.
//!
//! ## Autoregressive GenAI serving
//!
//! A [`Request`] with `decode_tokens > 0` is a GenAI request: the model's
//! prefill ingests its prompt (producing the first token — the TTFT
//! anchor) and `decode_tokens − 1` single-token decode steps follow, each
//! running the KV-length bucket of the model's
//! [`crate::coordinator::DecodeJob`] that covers its growing context
//! ([`CompileCache::get_decode`] compiles the `O(log max_context)` bucket
//! ladder). KV caches are Input tensors of the decode-step graphs, so
//! their DDR streaming is priced inside the emitted programs; with
//! [`SchedulerOptions::weight_residency`] a sequence's cache can stay
//! TCM-resident between steps ([`KV_OWNER_BASE`] owners in the same
//! [`crate::arch::TcmResidency`] the weights use), eliding that streaming
//! until capacity pressure evicts it — after which the sequence re-pays
//! the stream as a preemption refetch.
//! [`SchedulerOptions::continuous_batch`] switches decode from
//! request-boundary scheduling (one sequence owns its instance from
//! prefill to last token, cold program replay per step) to per-token
//! rounds where sequences join at prefill end and the model's decode
//! weights stay pinned while it has active sequences. TTFT, TPOT and
//! tokens/s land in [`ServeReport`]; `docs/genai.md` is the guide.
//!
//! ## Energy accounting
//!
//! With [`SchedulerOptions::energy`] on, every dispatch's ticks are
//! priced into femtojoules by the [`crate::energy::EnergyModel`] derived
//! from the config — same tick walk, same DMA-counting filters as the
//! timing path, so batching/residency/pipelining discounts carry over to
//! joules automatically. Completions carry their exactly-conserved
//! compute/DMA/idle split, [`ServeReport`] adds joules per inference and
//! per token (plus fleet-wide inter-dispatch idle energy), and two knobs
//! spend the meter: [`SchedulerOptions::energy_mode`] (`race-to-idle` vs
//! `stretch`) and [`SchedulerOptions::energy_budget_fj`] (class-ordered
//! shedding as the budget drains). Off, the meter reads zero and every
//! report and trace byte is unchanged. `docs/energy.md` is the guide.
//!
//! ## Virtual-clock contract
//!
//! All serving time lives on a shared **virtual clock** denominated in NPU
//! core cycles; the host wall clock never enters any reported number:
//!
//! * request arrivals, models and priority classes come from a seeded PRNG
//!   trace ([`synthetic_trace_with_mix`]) — same
//!   `(models, requests, mean gap, seed, mix)` yields the identical trace;
//! * the service time of a request is the simulated latency of its cached
//!   job program — a pure function of `(model, config)`; a batch follower
//!   pays only [`marginal_service_cycles`] (weights already resident);
//! * dispatch picks the pending request with the lowest
//!   `(effective class rank, admission order)` key among requests that
//!   have arrived by the decision time, onto the instance that goes idle
//!   earliest, ties broken toward the lowest instance id;
//! * event order is fixed: every dispatch whose start time is ≤ an
//!   arrival's timestamp runs before that arrival is admitted ("service
//!   precedes admission at equal times"), and admission-control decisions
//!   see the queue in exactly that state;
//! * per-request latency = queueing delay + service time, both in cycles
//!   on the shared clock;
//! * pipelining overlap windows, residency hit/miss/eviction decisions
//!   and warm-routing placements all derive from the same deterministic
//!   state (the dispatch history), never from host time — with every new
//!   knob off, the scheduler reproduces the pre-pipelining timing bit for
//!   bit (the differential executor suite locks this down).
//!
//! **Determinism:** same seed + same request trace + same options (+ same
//! config) → identical [`ServeReport`], across runs and across machines —
//! including the shed set, the batch composition and every percentile. To
//! make the cached programs themselves reproducible, serving compiles
//! under [`deterministic_compile_options`]: every CP budget is a node
//! limit (deterministic) instead of a wall-clock limit.
//!
//! See `docs/serving.md` for the end-to-end guide to this layer.

#![warn(missing_docs)]

pub mod cache;
pub mod queue;
pub mod server;

pub use cache::{
    calibration_fingerprint, calibration_l1_distance, config_fingerprint,
    deterministic_compile_options, CachedModel, CompileCache, DECODE_BUCKET_MIN_KV,
};
pub use queue::{
    marginal_service_cycles, synthetic_decode_trace, synthetic_trace, synthetic_trace_with_mix,
    Admission, AdmissionPolicy, Completion, NpuInstance, Priority, PriorityMix, Request,
    Scheduler, SchedulerOptions, KV_OWNER_BASE, MAX_MEAN_GAP_CYCLES,
};
pub use server::{
    report_from_outcome, run_trace, run_trace_recorded, serve, serve_with_cache,
    serve_with_cache_recorded, ClassStats, ModelStats, ServeOptions, ServeReport, TraceOutcome,
};
