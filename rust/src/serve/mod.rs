//! Multi-tenant serving layer: compile cache + admission queue + request
//! scheduler over N virtual NPU instances.
//!
//! The paper's headline claim is *utilization*, not peak TOPS — the stack
//! wins by keeping compute busy. This module turns the single-shot
//! coordinator into a serving simulator for the realistic deployment
//! shape: many models, many tenants, heavy traffic.
//!
//! Three pieces:
//!
//! * [`CompileCache`] — memoizes `compile` + `emit` per
//!   `(ModelId, NeutronConfig fingerprint)`, so repeat requests skip the CP
//!   solver entirely;
//! * [`Scheduler`] — a FIFO admission queue dispatching onto the
//!   earliest-idle of N virtual NPU instances (each a re-entrant
//!   `coordinator::Executor`);
//! * [`serve`] / [`ServeReport`] — runs a synthetic trace and reports
//!   throughput, p50/p95/p99 latency, cache hit rate and utilization.
//!
//! ## Virtual-clock contract
//!
//! All serving time lives on a shared **virtual clock** denominated in NPU
//! core cycles; the host wall clock never enters any reported number:
//!
//! * request arrivals come from a seeded PRNG trace
//!   ([`synthetic_trace`]) — same `(models, requests, mean gap, seed)`
//!   yields the identical trace;
//! * the service time of a request is the simulated latency of its cached
//!   job program — a pure function of `(model, config)`;
//! * dispatch is FIFO in admission order onto the instance that goes idle
//!   earliest, ties broken toward the lowest instance id;
//! * per-request latency = queueing delay + simulated service time, both
//!   in cycles on the shared clock.
//!
//! **Determinism:** same seed + same request trace (+ same config) →
//! identical [`ServeReport`], across runs and across machines. To make the
//! cached programs themselves reproducible, serving compiles under
//! [`deterministic_compile_options`]: every CP budget is a node limit
//! (deterministic) instead of a wall-clock limit.

pub mod cache;
pub mod queue;
pub mod server;

pub use cache::{config_fingerprint, deterministic_compile_options, CachedModel, CompileCache};
pub use queue::{synthetic_trace, Completion, NpuInstance, Request, Scheduler};
pub use server::{run_trace, serve, serve_with_cache, ModelStats, ServeOptions, ServeReport};
