//! Tick-based DAE simulator of the Neutron subsystem.
//!
//! Replays a compiled schedule against the architecture model,
//! *independently* re-deriving tick latencies (the compiler's estimates are
//! not trusted), enforcing the platform rules the compiler must respect:
//!
//!   * ≤ 1 compute job per tick; any number of datamover jobs;
//!   * all DDR transfers in a tick share the 12 GB/s DDR port (serialized
//!     by bandwidth); TCM-to-TCM copies run on the internal bus in
//!     parallel with DDR traffic;
//!   * bank exclusivity: a tick in which the compute job and a datamover
//!     job touch the same physical bank is a conflict — counted, and in
//!     checked mode fatal (the silicon would corrupt data, Sec. III-C);
//!   * V2P updates replay at their scheduled ticks.
//!
//! Produces a [`SimReport`] with the per-tick trace that Fig. 4 (DAE
//! pipeline) and Fig. 6 (memory over time) are drawn from.

use std::collections::HashMap;

use crate::arch::{NeutronConfig, Transfer, TransferKind};
use crate::compiler::{Allocation, Compiled, TiledProgram};

/// Per-tick trace entry.
#[derive(Debug, Clone, Default)]
pub struct TickTrace {
    pub tick: usize,
    pub compute_cycles: u64,
    pub ddr_cycles: u64,
    pub tcm_copy_cycles: u64,
    /// max(compute, ddr, tcm) — the tick's wall time.
    pub latency: u64,
    /// Banks resident after this tick.
    pub resident_banks: usize,
    /// Bytes resident after this tick (finer-grain Fig. 6 signal).
    pub resident_bytes: u64,
}

/// Simulation result.
#[derive(Debug, Clone, Default)]
pub struct SimReport {
    pub ticks: Vec<TickTrace>,
    pub total_cycles: u64,
    pub latency_ms: f64,
    pub ddr_bytes: u64,
    pub peak_resident_banks: usize,
    pub bank_conflicts: usize,
    pub v2p_updates: usize,
}

impl SimReport {
    /// Effective TOPS given the graph's MAC count.
    pub fn effective_tops(&self, total_macs: u64) -> f64 {
        2.0 * total_macs as f64 / (self.latency_ms * 1e-3) / 1e12
    }

    /// Fraction of ticks where datamover work was fully hidden behind
    /// compute (the Fig. 4 DAE story).
    pub fn hiding_ratio(&self) -> f64 {
        let dm_ticks = self
            .ticks
            .iter()
            .filter(|t| t.ddr_cycles + t.tcm_copy_cycles > 0)
            .count();
        if dm_ticks == 0 {
            return 1.0;
        }
        let hidden = self
            .ticks
            .iter()
            .filter(|t| {
                t.ddr_cycles + t.tcm_copy_cycles > 0
                    && t.compute_cycles >= t.ddr_cycles.max(t.tcm_copy_cycles)
            })
            .count();
        hidden as f64 / dm_ticks as f64
    }
}

/// Simulator options.
#[derive(Debug, Clone)]
pub struct SimOptions {
    /// Panic on bank conflicts (strict hardware semantics) vs count them.
    pub strict_banks: bool,
    /// Simulate the monolithic (non-DAE) pipeline of Fig. 4: datamover and
    /// compute serialize within a tick.
    pub serialize_dae: bool,
}

impl Default for SimOptions {
    fn default() -> Self {
        Self { strict_banks: false, serialize_dae: false }
    }
}

/// Run the simulator over a compiled artifact.
pub fn simulate(c: &Compiled, cfg: &NeutronConfig, opts: &SimOptions) -> SimReport {
    simulate_parts(&c.program, &c.schedule, &c.allocation, cfg, opts)
}

/// Run from the individual compiler products.
pub fn simulate_parts(
    prog: &TiledProgram,
    sched: &crate::compiler::Schedule,
    alloc: &Allocation,
    cfg: &NeutronConfig,
    opts: &SimOptions,
) -> SimReport {
    let mut report = SimReport::default();
    let mut resident: HashMap<crate::compiler::TileId, ()> = HashMap::new();
    // Pending V2P updates grouped by tick.
    let mut v2p_by_tick: HashMap<usize, usize> = HashMap::new();
    for &(tick, _, _) in &alloc.v2p_updates {
        *v2p_by_tick.entry(tick).or_insert(0) += 1;
    }

    let last_use = last_use_map(prog, sched);
    for (ti, tick) in sched.ticks.iter().enumerate() {
        let mut tr = TickTrace { tick: ti, ..Default::default() };

        // Datamover side: DDR jobs share the port; TCM copies their bus.
        let mut ddr_bytes_tick = 0u64;
        let mut tcm_bytes_tick = 0u64;
        for t in &tick.transfers {
            if t.kind.uses_ddr() {
                ddr_bytes_tick += t.bytes;
                report.ddr_bytes += t.bytes;
            } else {
                tcm_bytes_tick += t.bytes;
            }
            match t.kind {
                TransferKind::Fetch | TransferKind::LFetch => {
                    resident.insert(t.tile, ());
                }
                TransferKind::Push => {
                    resident.remove(&t.tile);
                }
                TransferKind::LCopy => {}
            }
        }
        // Bandwidth-serialized DDR stream + exposed per-job setup.
        if ddr_bytes_tick > 0 {
            let n_jobs = tick.transfers.iter().filter(|t| t.kind.uses_ddr()).count() as u64;
            tr.ddr_cycles = (ddr_bytes_tick as f64 / cfg.ddr_bytes_per_cycle()).ceil() as u64
                + n_jobs * cfg.job_overhead_cycles / 4;
        }
        if tcm_bytes_tick > 0 {
            tr.tcm_copy_cycles = tcm_bytes_tick.div_ceil(cfg.bus_bytes as u64);
        }

        // Compute side: re-derive from the step (includes job overhead).
        if let Some(si) = tick.compute {
            let step = &prog.steps[si];
            tr.compute_cycles = step.cycles;
            resident.insert(step.out_tile, ());

            // Bank-exclusivity check: physical banks of compute operands vs
            // banks of concurrently transferred tiles.
            let compute_banks: Vec<usize> = step
                .in_tiles
                .iter()
                .chain(step.param_tile.iter())
                .chain(std::iter::once(&step.out_tile))
                .filter_map(|t| alloc.placements.get(t))
                .flat_map(|p| p.range())
                .collect();
            for t in &tick.transfers {
                // TCM-side banks of the transfer.
                if let Some(p) = alloc.placements.get(&t.tile) {
                    // l-copy expansion works in the tensor's own banks and
                    // is sequenced by the controller, not a conflict.
                    if t.kind == TransferKind::LCopy {
                        continue;
                    }
                    if p.range().any(|b| compute_banks.contains(&b)) {
                        report.bank_conflicts += 1;
                        if opts.strict_banks {
                            panic!(
                                "bank conflict at tick {ti}: transfer of tile {:?} \
                                 overlaps compute operand banks",
                                t.tile
                            );
                        }
                        // Non-strict: the hardware would stall — serialize.
                        tr.ddr_cycles += Transfer::new(t.kind, t.bytes).cycles(cfg) / 2;
                    }
                }
            }
        }

        report.v2p_updates += v2p_by_tick.remove(&ti).unwrap_or(0);

        // Drop tiles whose last use has passed (zero-cost transition).
        resident.retain(|t, _| last_use.get(t).is_none_or(|&l| l >= ti));

        tr.resident_banks = resident
            .keys()
            .filter_map(|t| alloc.placements.get(t))
            .map(|p| p.banks)
            .sum();
        tr.resident_bytes = resident.keys().map(|t| prog.tile(*t).bytes).sum();
        report.peak_resident_banks = report.peak_resident_banks.max(tr.resident_banks);

        tr.latency = if opts.serialize_dae {
            tr.compute_cycles + tr.ddr_cycles + tr.tcm_copy_cycles
        } else {
            tr.compute_cycles.max(tr.ddr_cycles).max(tr.tcm_copy_cycles)
        };
        report.total_cycles += tr.latency;
        report.ticks.push(tr);
    }
    report.latency_ms = cfg.cycles_to_ms(report.total_cycles);
    report
}

fn last_use_map(
    prog: &TiledProgram,
    sched: &crate::compiler::Schedule,
) -> HashMap<crate::compiler::TileId, usize> {
    let mut m = HashMap::new();
    for (ti, tick) in sched.ticks.iter().enumerate() {
        if let Some(si) = tick.compute {
            let s = &prog.steps[si];
            m.insert(s.out_tile, ti);
            for &t in &s.in_tiles {
                m.insert(t, ti);
            }
            if let Some(p) = s.param_tile {
                m.insert(p, ti);
            }
        }
        for t in &tick.transfers {
            m.insert(t.tile, ti);
        }
    }
    m
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::{compile, CompileOptions};
    use crate::zoo;

    fn sim(g: &crate::ir::Graph, opts: &SimOptions) -> (Compiled, SimReport) {
        let cfg = NeutronConfig::flagship_2tops();
        let c = compile(g, &cfg, &CompileOptions::default_partitioned());
        let r = simulate(&c, &cfg, opts);
        (c, r)
    }

    #[test]
    fn sim_latency_close_to_compiler_estimate() {
        let g = zoo::mobilenet::mobilenet_v2();
        let (c, r) = sim(&g, &SimOptions::default());
        let ratio = r.latency_ms / c.inference_ms;
        assert!(
            (0.5..2.0).contains(&ratio),
            "sim {} vs est {} (ratio {ratio})",
            r.latency_ms,
            c.inference_ms
        );
    }

    #[test]
    fn dae_mode_is_faster_than_serialized() {
        let g = zoo::mobilenet::mobilenet_v1();
        let (_, dae) = sim(&g, &SimOptions::default());
        let (_, ser) = sim(&g, &SimOptions { serialize_dae: true, ..Default::default() });
        assert!(dae.total_cycles < ser.total_cycles);
    }

    #[test]
    fn memory_trace_is_bounded_by_tcm() {
        let g = zoo::mobilenet::mobilenet_v2();
        let cfg = NeutronConfig::flagship_2tops();
        let (_, r) = sim(&g, &SimOptions::default());
        // Belady + capacity constraints keep residency within ~C (small
        // transient overshoot allowed at whole-bank granularity).
        assert!(
            r.peak_resident_banks <= cfg.tcm_banks + cfg.tcm_banks / 4,
            "peak {} banks",
            r.peak_resident_banks
        );
    }

    #[test]
    fn ddr_traffic_matches_schedule_accounting() {
        let g = zoo::mobilenet::mobilenet_v1();
        let (c, r) = sim(&g, &SimOptions::default());
        assert_eq!(r.ddr_bytes, c.schedule.ddr.total_bytes());
    }

    #[test]
    fn effective_tops_sane() {
        let g = zoo::mobilenet::mobilenet_v1();
        let cfg = NeutronConfig::flagship_2tops();
        let (_, r) = sim(&g, &SimOptions::default());
        let eff = r.effective_tops(g.total_macs());
        assert!(eff > 0.1 && eff <= cfg.peak_tops(), "eff={eff}");
    }

    /// Hand-built one-tick schedule: a compute step reading tile 0 (bank 0)
    /// and writing tile 1 (bank 1), while tile 2 streams in concurrently.
    /// With `conflict` the streamed tile lands in bank 0 — the compute
    /// operand's bank — otherwise in its own bank 2.
    fn hand_built(conflict: bool) -> (TiledProgram, crate::compiler::Schedule, Allocation) {
        use crate::compiler::scheduling::{ScheduledTransfer, Tick};
        use crate::compiler::{ComputeStep, Placement, Tile, TileId};
        use crate::ir::{OpId, TensorId};

        let tile = |id: u32, tensor: u32, in_dram: bool| Tile {
            id: TileId(id),
            tensor: TensorId(tensor),
            part: (0, 1),
            rows: 1,
            bytes: 64,
            banks: 1,
            starts_in_dram: in_dram,
            is_graph_output: false,
        };
        let tiles = vec![tile(0, 0, true), tile(1, 1, false), tile(2, 2, true)];
        let steps = vec![ComputeStep {
            op: OpId(0),
            out_tile: TileId(1),
            in_tiles: vec![TileId(0)],
            param_tile: None,
            format: crate::arch::Format::Depth,
            cycles: 1_000,
            needs_line_expand: false,
        }];
        let prog = TiledProgram { tiles, steps, residency_banks: vec![3] };
        let tick = Tick {
            compute: Some(0),
            transfers: vec![ScheduledTransfer {
                tile: TileId(2),
                kind: TransferKind::Fetch,
                cycles: 200,
                bytes: 64,
            }],
            compute_cycles: 1_000,
            dm_cycles: 200,
        };
        let sched = crate::compiler::Schedule { ticks: vec![tick], ..Default::default() };
        let mut alloc = Allocation::default();
        alloc.placements.insert(TileId(0), Placement { first_bank: 0, banks: 1 });
        alloc.placements.insert(TileId(1), Placement { first_bank: 1, banks: 1 });
        let streamed_bank = if conflict { 0 } else { 2 };
        alloc
            .placements
            .insert(TileId(2), Placement { first_bank: streamed_bank, banks: 1 });
        (prog, sched, alloc)
    }

    #[test]
    fn known_bank_conflict_counts_exactly_one_in_nonstrict_mode() {
        let cfg = NeutronConfig::flagship_2tops();
        let (p, s, a) = hand_built(true);
        let r = simulate_parts(&p, &s, &a, &cfg, &SimOptions::default());
        assert_eq!(r.bank_conflicts, 1);
        // The stall serializes part of the transfer behind compute.
        assert!(r.total_cycles >= 1_000);

        let (p, s, a) = hand_built(false);
        let r = simulate_parts(&p, &s, &a, &cfg, &SimOptions::default());
        assert_eq!(r.bank_conflicts, 0);
    }

    #[test]
    fn strict_banks_panics_on_known_conflict() {
        let cfg = NeutronConfig::flagship_2tops();
        let strict = SimOptions { strict_banks: true, ..Default::default() };

        let (p, s, a) = hand_built(true);
        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            simulate_parts(&p, &s, &a, &cfg, &strict)
        }));
        assert!(caught.is_err(), "strict mode must panic on a bank conflict");

        // A conflict-free schedule passes strict mode untouched.
        let (p, s, a) = hand_built(false);
        let r = simulate_parts(&p, &s, &a, &cfg, &strict);
        assert_eq!(r.bank_conflicts, 0);
        assert_eq!(r.ticks.len(), 1);
    }
}
