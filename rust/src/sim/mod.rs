//! Tick-based decoupled-access-execute simulator: replays compiled
//! schedules against the architecture model with bank/bus/DDR contention,
//! producing the traces behind Fig. 4 and Fig. 6.

pub mod npu;

pub use npu::{simulate, simulate_parts, SimOptions, SimReport, TickTrace};
