//! Branch-and-bound search over a [`CpModel`].
//!
//! Depth-first search with trail-based backtracking:
//!   * presolve propagation at the root;
//!   * deterministic variable selection (smallest remaining domain, ties by
//!     index — keeps compile results reproducible run-to-run), optionally
//!     refined by last-conflict-first branching ([`SearchConfig::last_conflict`]);
//!   * value ordering steered by the objective (try the value that pulls the
//!     objective down first);
//!   * objective-bound pruning against the incumbent;
//!   * node and wall-time limits with best-effort (incumbent) results, the
//!     behaviour the paper relies on when it trades schedule quality for
//!     compile time (Table II).
//!
//! Two interchangeable propagation engines back the search: the incremental
//! cached-activity engine ([`super::propagate`], the default) and the frozen
//! recompute-per-visit oracle ([`super::reference`]). Both explore the exact
//! same tree — see `docs/solver.md` for the equivalence argument — and every
//! solve reports a deterministic [`SolveStats`] alongside the result.

use std::time::Instant;

use super::model::{CpModel, Var};
use super::propagate::{expr_min, Domains, PropResult, Propagator, TrailEntry};

/// Which propagation engine backs the search. Results (status, objective,
/// assignment, node count) are identical by construction; only wall time and
/// the propagation-layer counters differ.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub enum EngineKind {
    /// Cached-activity incremental engine (production default).
    #[default]
    Incremental,
    /// Frozen recompute-per-visit oracle, kept for differential testing and
    /// old-vs-new benchmarking.
    Reference,
}

/// Search configuration.
#[derive(Debug, Clone)]
pub struct SearchConfig {
    /// Abort after this many explored nodes (None = unlimited).
    pub node_limit: Option<u64>,
    /// Abort after this wall-clock budget in milliseconds (None = unlimited).
    pub time_limit_ms: Option<u64>,
    /// Stop at the first feasible solution (ignore optimality).
    pub first_solution_only: bool,
    /// Warm-start hint: a full assignment (indexed by var index). If it
    /// satisfies the model it becomes the initial incumbent, so the search
    /// can only improve on it — and prunes against it from node one. An
    /// invalid hint is dropped and counted in [`SolveStats::hints_rejected`].
    pub hint: Option<Vec<i64>>,
    /// Last-conflict-first branching: keep branching on the variable whose
    /// decision most recently caused a failure, as long as it is unfixed.
    /// Off by default — the compiler passes rely on the documented
    /// smallest-domain order for byte-stable artifacts; flip it only for
    /// experiments (both engines honor it identically).
    pub last_conflict: bool,
    /// Test instrumentation: recompute the incremental engine's cached
    /// activities from scratch after every backtrack and panic on any
    /// divergence. O(model) per node — never enable on production paths.
    pub validate: bool,
    /// Propagation engine selection (default [`EngineKind::Incremental`]).
    pub engine: EngineKind,
}

impl Default for SearchConfig {
    fn default() -> Self {
        Self {
            node_limit: Some(2_000_000),
            time_limit_ms: Some(20_000),
            first_solution_only: false,
            hint: None,
            last_conflict: false,
            validate: false,
            engine: EngineKind::Incremental,
        }
    }
}

impl SearchConfig {
    /// Warm-start from a prior [`Solution`]: folds its assignment into
    /// [`SearchConfig::hint`], so a compatible, still-feasible prior result
    /// becomes the incumbent at node one and the search is *anytime* — a
    /// node-budget expiry returns the seed (or something strictly better)
    /// instead of failing. A seed without an assignment, with the wrong
    /// arity, or violating the model is dropped by the hint validation in
    /// [`solve`] (and counted in [`SolveStats::hints_rejected`]) —
    /// warm-starting degrades to a cold search, never to a wrong answer.
    pub fn with_seed(mut self, seed: &Solution) -> Self {
        if let Some(a) = &seed.assignment {
            self.hint = Some(a.clone());
        }
        self
    }
}

/// Why the search returned.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Status {
    /// Proven optimal (or proven feasible with no objective).
    Optimal,
    /// A solution was found but the search hit a limit before proving
    /// optimality.
    Feasible,
    /// Proven infeasible.
    Infeasible,
    /// Limit hit before any solution was found.
    Unknown,
}

/// Deterministic solver counters, reported with every [`Solution`] and
/// aggregated across the compiler's CP subproblems. Under pure node budgets
/// every field is a pure function of (model, config) — wall time never leaks
/// in — so stats can participate in golden comparisons.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct SolveStats {
    /// Explored branch-and-bound nodes (mirrors [`Solution::nodes`] so the
    /// count survives cross-pass aggregation, where individual `Solution`s
    /// are long gone).
    pub nodes: u64,
    /// Constraint visits during propagation (queue pops that ran a tightener).
    pub propagations: u64,
    /// Successful bound changes (a lower bound raised or upper bound lowered).
    pub tightenings: u64,
    /// Constraints proven trivially satisfied and unwatched until backtrack
    /// (always 0 for [`EngineKind::Reference`], which has no entailment).
    pub entailments: u64,
    /// Trail unwind operations performed by the search.
    pub backtracks: u64,
    /// Deepest trail (total trailed events) reached during the solve.
    pub peak_trail: u64,
    /// Warm-start hints that failed validation (wrong arity or violating the
    /// model) and were dropped — the silent-cold-search signal.
    pub hints_rejected: u64,
}

impl SolveStats {
    /// Fold another solve's counters into this one: sums everywhere except
    /// `peak_trail`, which takes the max (it is a depth, not a volume).
    pub fn merge(&mut self, other: &SolveStats) {
        self.nodes += other.nodes;
        self.propagations += other.propagations;
        self.tightenings += other.tightenings;
        self.entailments += other.entailments;
        self.backtracks += other.backtracks;
        self.peak_trail = self.peak_trail.max(other.peak_trail);
        self.hints_rejected += other.hints_rejected;
    }
}

/// Search outcome.
#[derive(Debug, Clone)]
pub struct Solution {
    pub status: Status,
    /// Best assignment found (indexed by var index), if any.
    pub assignment: Option<Vec<i64>>,
    /// Objective of the best assignment.
    pub objective: Option<i64>,
    /// Explored node count.
    pub nodes: u64,
    /// Wall time of the solve in milliseconds. The only nondeterministic
    /// field of a `Solution` — it is deliberately excluded from the
    /// [`PartialEq`] surface so whole solutions can be golden-compared.
    pub solve_ms: u64,
    /// Deterministic solver counters for this solve.
    pub stats: SolveStats,
}

/// Equality over the *deterministic* surface only: `solve_ms` is wall clock
/// and is ignored, so two runs of the same (model, config) compare equal.
impl PartialEq for Solution {
    fn eq(&self, other: &Self) -> bool {
        self.status == other.status
            && self.assignment == other.assignment
            && self.objective == other.objective
            && self.nodes == other.nodes
            && self.stats == other.stats
    }
}

impl Eq for Solution {}

/// Why [`Solution::value`] could not produce a value.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ValueError {
    /// The search ended without any assignment (`Infeasible`/`Unknown`).
    NoSolution,
    /// The variable does not belong to the solved model: its index lies
    /// outside the assignment (e.g. a `Var` from a different `CpModel`).
    NoSuchVar {
        /// Index of the offending variable.
        index: usize,
        /// Number of variables in the solved model.
        num_vars: usize,
    },
}

impl std::fmt::Display for ValueError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ValueError::NoSolution => write!(f, "no solution: search found no assignment"),
            ValueError::NoSuchVar { index, num_vars } => write!(
                f,
                "variable index {index} is not in the solved model ({num_vars} vars)"
            ),
        }
    }
}

impl std::error::Error for ValueError {}

impl Solution {
    /// Value of a variable in the best assignment. Returns a structured
    /// error instead of panicking when there is no assignment or when `v`
    /// comes from a different model than the one solved.
    pub fn value(&self, v: Var) -> Result<i64, ValueError> {
        let a = self.assignment.as_ref().ok_or(ValueError::NoSolution)?;
        a.get(v.index()).copied().ok_or(ValueError::NoSuchVar {
            index: v.index(),
            num_vars: a.len(),
        })
    }

    /// True if a usable assignment exists.
    pub fn has_solution(&self) -> bool {
        self.assignment.is_some()
    }
}

/// Validate a warm-start hint against the model; shared by both engines so
/// the rejection accounting can never diverge. Returns the initial incumbent
/// (objective, assignment) and the number of rejected hints (0 or 1).
pub(crate) fn validate_hint(
    model: &CpModel,
    cfg: &SearchConfig,
    obj_terms: &[(i64, Var)],
    obj_const: i64,
) -> (Option<(i64, Vec<i64>)>, u64) {
    match cfg.hint.as_ref() {
        Some(h) if h.len() == model.vars.len() && model.violated(h).is_none() => {
            let obj = obj_const
                + obj_terms
                    .iter()
                    .map(|&(c, v)| c * h[v.index()])
                    .sum::<i64>();
            (Some((obj, h.clone())), 0)
        }
        Some(_) => (None, 1),
        None => (None, 0),
    }
}

/// Normalized objective terms (sorted by var, for binary search) + constant.
pub(crate) fn objective_terms(model: &CpModel) -> (Vec<(i64, Var)>, i64) {
    let (mut terms, constant) = match &model.objective {
        Some(o) => (o.terms.clone(), o.constant),
        None => (Vec::new(), 0),
    };
    terms.sort_by_key(|&(_, v)| v);
    (terms, constant)
}

struct SearchCtx<'m> {
    model: &'m CpModel,
    prop: Propagator,
    dom: Domains,
    trail: Vec<TrailEntry>,
    /// Objective terms (empty if satisfaction problem).
    obj_terms: Vec<(i64, Var)>,
    obj_const: i64,
    best: Option<(i64, Vec<i64>)>,
    nodes: u64,
    start: Instant,
    cfg: SearchConfig,
    limit_hit: bool,
    backtracks: u64,
    peak_trail: u64,
    last_conflict: Option<Var>,
}

impl<'m> SearchCtx<'m> {
    /// Unwind the trail to `mark` through the engine (which restores its
    /// activity caches), recording depth and backtrack stats.
    fn backtrack_to(&mut self, mark: usize) {
        self.peak_trail = self.peak_trail.max(self.trail.len() as u64);
        self.backtracks += 1;
        self.prop.undo_to(&mut self.dom, &mut self.trail, mark);
        if self.cfg.validate {
            self.prop.check_invariants(self.model, &self.dom);
        }
    }

    fn limits_exceeded(&mut self) -> bool {
        if self.limit_hit {
            return true;
        }
        if let Some(n) = self.cfg.node_limit {
            if self.nodes >= n {
                self.limit_hit = true;
                return true;
            }
        }
        if let Some(ms) = self.cfg.time_limit_ms {
            // Check time only periodically — Instant::now is not free.
            if self.nodes % 256 == 0 && self.start.elapsed().as_millis() as u64 >= ms {
                self.limit_hit = true;
                return true;
            }
        }
        false
    }

    /// Pick the branching variable: the last conflicting variable if that
    /// refinement is enabled and it is still unfixed, else the unfixed var
    /// with the smallest domain, ties broken by index for determinism.
    /// Returns None if all fixed.
    fn select_var(&self) -> Option<Var> {
        if self.cfg.last_conflict {
            if let Some(v) = self.last_conflict {
                if self.dom.ub(v) > self.dom.lb(v) {
                    return Some(v);
                }
            }
        }
        let mut best: Option<(i64, usize)> = None;
        for i in 0..self.dom.lb.len() {
            let w = self.dom.ub[i] - self.dom.lb[i];
            if w > 0 {
                match best {
                    Some((bw, _)) if bw <= w => {}
                    _ => best = Some((w, i)),
                }
            }
        }
        best.map(|(_, i)| Var(i as u32))
    }

    /// Objective coefficient of `v` (0 if absent). Objective terms are
    /// normalized, so binary search applies.
    fn obj_coef(&self, v: Var) -> i64 {
        self.obj_terms
            .binary_search_by_key(&v, |&(_, var)| var)
            .map(|i| self.obj_terms[i].0)
            .unwrap_or(0)
    }

    fn dfs(&mut self) {
        self.nodes += 1;
        if self.limits_exceeded() {
            return;
        }

        // Objective-bound pruning.
        if let Some((best_obj, _)) = &self.best {
            let lb = expr_min(&self.obj_terms, self.obj_const, &self.dom);
            if lb >= *best_obj {
                return;
            }
        }

        let Some(v) = self.select_var() else {
            // All vars fixed ⇒ propagation already verified consistency.
            let assignment = self.dom.assignment();
            let obj = expr_min(&self.obj_terms, self.obj_const, &self.dom);
            debug_assert!(self.model.violated(&assignment).is_none());
            let better = match &self.best {
                Some((b, _)) => obj < *b,
                None => true,
            };
            if better {
                self.best = Some((obj, assignment));
            }
            return;
        };

        // Value ordering: if the objective rewards small values (coef ≥ 0)
        // try lb first, else ub first. Branch as x = bound vs x ≠ bound.
        let coef = self.obj_coef(v);
        let lb_first = coef >= 0;
        let (first_is_lb, second_is_lb) = (lb_first, !lb_first);
        for is_lb in [first_is_lb, second_is_lb] {
            if self.limit_hit {
                return;
            }
            // With an incumbent we still need to explore both branches.
            let mark = self.trail.len();
            // Branch x = bound, routed through the engine so the activity
            // caches follow; the decision enqueues the affected watchers.
            if is_lb {
                let val = self.dom.lb(v);
                self.prop.branch_ub(v, val, &mut self.dom, &mut self.trail);
            } else {
                let val = self.dom.ub(v);
                self.prop.branch_lb(v, val, &mut self.dom, &mut self.trail);
            }
            let res = self.prop.run(self.model, &mut self.dom, &mut self.trail);
            if res == PropResult::Consistent {
                self.dfs();
                if self.cfg.first_solution_only && self.best.is_some() {
                    self.backtrack_to(mark);
                    return;
                }
            } else {
                self.last_conflict = Some(v);
            }
            self.backtrack_to(mark);

            // Second branch excludes the tried bound: x ≥ lb+1 (or ≤ ub-1).
            // Applied before the loop's second iteration via domain shrink.
            if is_lb == first_is_lb {
                let mark2 = self.trail.len();
                let feas = if first_is_lb {
                    let nv = self.dom.lb(v) + 1;
                    if nv > self.dom.ub(v) {
                        false
                    } else {
                        self.prop.branch_lb(v, nv, &mut self.dom, &mut self.trail);
                        true
                    }
                } else {
                    let nv = self.dom.ub(v) - 1;
                    if nv < self.dom.lb(v) {
                        false
                    } else {
                        self.prop.branch_ub(v, nv, &mut self.dom, &mut self.trail);
                        true
                    }
                };
                if !feas {
                    return; // domain exhausted; both branches done
                }
                let res = self.prop.run(self.model, &mut self.dom, &mut self.trail);
                if res == PropResult::Infeasible {
                    self.last_conflict = Some(v);
                    self.backtrack_to(mark2);
                    return;
                }
                // Recurse over the reduced domain instead of a literal
                // second value: gives binary-tree branching on ranges.
                self.dfs();
                self.backtrack_to(mark2);
                return;
            }
        }
    }
}

/// Solve `model` with the given configuration, dispatching to the engine
/// selected by [`SearchConfig::engine`].
pub fn solve(model: &CpModel, cfg: SearchConfig) -> Solution {
    match cfg.engine {
        EngineKind::Incremental => solve_incremental(model, cfg),
        EngineKind::Reference => super::reference::solve_reference(model, cfg),
    }
}

fn solve_incremental(model: &CpModel, cfg: SearchConfig) -> Solution {
    let start = Instant::now();
    let mut dom = Domains::from_model(model);
    let mut prop = Propagator::new(model);
    let mut trail = Vec::new();

    let (obj_terms, obj_const) = objective_terms(model);
    // Warm start: adopt a valid hint as the initial incumbent; count drops.
    let (initial_best, hints_rejected) = validate_hint(model, &cfg, &obj_terms, obj_const);

    // Root presolve.
    if prop.propagate_all(model, &mut dom, &mut trail) == PropResult::Infeasible {
        return Solution {
            status: Status::Infeasible,
            assignment: None,
            objective: None,
            nodes: 0,
            solve_ms: start.elapsed().as_millis() as u64,
            stats: SolveStats {
                nodes: 0,
                propagations: prop.counters.propagations,
                tightenings: prop.counters.tightenings,
                entailments: prop.counters.entailments,
                backtracks: 0,
                peak_trail: trail.len() as u64,
                hints_rejected,
            },
        };
    }
    if cfg.validate {
        prop.check_invariants(model, &dom);
    }

    let mut ctx = SearchCtx {
        model,
        prop,
        dom,
        trail,
        obj_terms,
        obj_const,
        best: initial_best,
        nodes: 0,
        start,
        cfg,
        limit_hit: false,
        backtracks: 0,
        peak_trail: 0,
        last_conflict: None,
    };
    ctx.dfs();

    let solve_ms = ctx.start.elapsed().as_millis() as u64;
    let stats = SolveStats {
        nodes: ctx.nodes,
        propagations: ctx.prop.counters.propagations,
        tightenings: ctx.prop.counters.tightenings,
        entailments: ctx.prop.counters.entailments,
        backtracks: ctx.backtracks,
        peak_trail: ctx.peak_trail.max(ctx.trail.len() as u64),
        hints_rejected,
    };
    match ctx.best {
        Some((obj, assignment)) => Solution {
            status: if ctx.limit_hit { Status::Feasible } else { Status::Optimal },
            objective: Some(obj),
            assignment: Some(assignment),
            nodes: ctx.nodes,
            solve_ms,
            stats,
        },
        None => Solution {
            status: if ctx.limit_hit { Status::Unknown } else { Status::Infeasible },
            objective: None,
            assignment: None,
            nodes: ctx.nodes,
            solve_ms,
            stats,
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cp::model::LinExpr;

    #[test]
    fn optimal_simple_lp() {
        // min x + y  s.t. x + y >= 3, x,y in [0,5]
        let mut m = CpModel::new();
        let x = m.int_var(0, 5, "x");
        let y = m.int_var(0, 5, "y");
        m.add_ge(LinExpr::sum([x, y]), 3);
        m.minimize(LinExpr::sum([x, y]));
        let s = solve(&m, SearchConfig::default());
        assert_eq!(s.status, Status::Optimal);
        assert_eq!(s.objective, Some(3));
    }

    #[test]
    fn infeasible_model() {
        let mut m = CpModel::new();
        let x = m.bool_var("x");
        m.add_ge(LinExpr::var(x), 1);
        m.add_le(LinExpr::var(x), 0);
        let s = solve(&m, SearchConfig::default());
        assert_eq!(s.status, Status::Infeasible);
    }

    #[test]
    fn knapsack_optimal() {
        // max 6a+5b+4c st 2a+3b+4c <= 5 → min -(...)
        let mut m = CpModel::new();
        let a = m.bool_var("a");
        let b = m.bool_var("b");
        let c = m.bool_var("c");
        m.add_le(LinExpr::new().add(2, a).add(3, b).add(4, c), 5);
        m.minimize(LinExpr::new().add(-6, a).add(-5, b).add(-4, c));
        let s = solve(&m, SearchConfig::default());
        assert_eq!(s.status, Status::Optimal);
        assert_eq!(s.objective, Some(-11)); // a + b
        assert_eq!(s.value(a), Ok(1));
        assert_eq!(s.value(b), Ok(1));
        assert_eq!(s.value(c), Ok(0));
    }

    #[test]
    fn exactly_one_selection() {
        // min cost with exactly-one constraint: costs 7, 3, 9
        let mut m = CpModel::new();
        let v: Vec<_> = (0..3).map(|i| m.bool_var(format!("s{i}"))).collect();
        m.add_exactly_one(v.clone());
        m.minimize(LinExpr::weighted_sum([(7, v[0]), (3, v[1]), (9, v[2])]));
        let s = solve(&m, SearchConfig::default());
        assert_eq!(s.objective, Some(3));
        assert_eq!(s.value(v[1]), Ok(1));
    }

    #[test]
    fn satisfaction_without_objective() {
        let mut m = CpModel::new();
        let x = m.int_var(0, 9, "x");
        let y = m.int_var(0, 9, "y");
        m.add_eq(LinExpr::new().add(1, x).add(1, y), 9);
        m.add_eq(LinExpr::new().add(1, x).add(-1, y), 3);
        let s = solve(&m, SearchConfig::default());
        assert!(s.has_solution());
        assert_eq!(s.value(x), Ok(6));
        assert_eq!(s.value(y), Ok(3));
    }

    #[test]
    fn node_limit_returns_feasible_or_unknown() {
        let mut m = CpModel::new();
        let vars: Vec<_> = (0..30).map(|i| m.bool_var(format!("b{i}"))).collect();
        // Loose parity-ish constraints to make a big tree.
        for w in vars.windows(2) {
            m.add_le(LinExpr::sum(w.to_vec()), 1);
        }
        m.minimize(LinExpr::weighted_sum(
            vars.iter().enumerate().map(|(i, &v)| (-(i as i64 % 7 + 1), v)),
        ));
        let s = solve(
            &m,
            SearchConfig { node_limit: Some(50), ..Default::default() },
        );
        assert!(matches!(s.status, Status::Feasible | Status::Unknown | Status::Optimal));
    }

    #[test]
    fn value_returns_structured_errors_instead_of_panicking() {
        // Infeasible model: no assignment at all.
        let mut m = CpModel::new();
        let x = m.bool_var("x");
        m.add_ge(LinExpr::var(x), 1);
        m.add_le(LinExpr::var(x), 0);
        let s = solve(&m, SearchConfig::default());
        assert_eq!(s.value(x), Err(ValueError::NoSolution));

        // Feasible model, but a Var from a *bigger* model: out of range.
        let mut small = CpModel::new();
        let a = small.bool_var("a");
        small.minimize(LinExpr::var(a));
        let s = solve(&small, SearchConfig::default());
        assert_eq!(s.value(a), Ok(0));
        let mut big = CpModel::new();
        let _ = big.bool_var("p");
        let q = big.bool_var("q");
        assert_eq!(
            s.value(q),
            Err(ValueError::NoSuchVar { index: 1, num_vars: 1 })
        );
        let msg = s.value(q).unwrap_err().to_string();
        assert!(msg.contains("index 1"), "{msg}");
    }

    #[test]
    fn seeded_search_adopts_incumbent_and_stays_anytime() {
        // min 3a+2b+c  s.t. a+b+c >= 2 — optimum is b=c=1 → 3.
        let mut m = CpModel::new();
        let a = m.bool_var("a");
        let b = m.bool_var("b");
        let c = m.bool_var("c");
        m.add_ge(LinExpr::sum([a, b, c]), 2);
        m.minimize(LinExpr::weighted_sum([(3, a), (2, b), (1, c)]));

        // A feasible but suboptimal prior solution (a=b=1 → 5).
        let prior = Solution {
            status: Status::Feasible,
            assignment: Some(vec![1, 1, 0]),
            objective: Some(5),
            nodes: 0,
            solve_ms: 0,
            stats: SolveStats::default(),
        };

        // Zero-node budget: the anytime search returns the seed itself.
        let cfg = SearchConfig {
            node_limit: Some(0),
            ..Default::default()
        }
        .with_seed(&prior);
        let s = solve(&m, cfg);
        assert_eq!(s.status, Status::Feasible);
        assert_eq!(s.objective, Some(5));

        // Unlimited budget: the seed never blocks reaching the optimum.
        let s = solve(&m, SearchConfig::default().with_seed(&prior));
        assert_eq!(s.status, Status::Optimal);
        assert_eq!(s.objective, Some(3));
    }

    #[test]
    fn invalid_seed_degrades_to_cold_search_and_is_counted() {
        let mut m = CpModel::new();
        let x = m.int_var(0, 5, "x");
        m.add_ge(LinExpr::var(x), 2);
        m.minimize(LinExpr::var(x));
        // Wrong arity and constraint-violating seeds are both dropped.
        for bad in [vec![0i64, 0], vec![0]] {
            let seed = Solution {
                status: Status::Feasible,
                assignment: Some(bad),
                objective: None,
                nodes: 0,
                solve_ms: 0,
                stats: SolveStats::default(),
            };
            let s = solve(&m, SearchConfig::default().with_seed(&seed));
            assert_eq!(s.status, Status::Optimal);
            assert_eq!(s.objective, Some(2));
            assert_eq!(s.stats.hints_rejected, 1);
        }
        // A valid seed is not counted.
        let s = solve(&m, SearchConfig { hint: Some(vec![3]), ..Default::default() });
        assert_eq!(s.stats.hints_rejected, 0);
    }

    #[test]
    fn deterministic_across_runs() {
        let mut m = CpModel::new();
        let vars: Vec<_> = (0..12).map(|i| m.bool_var(format!("b{i}"))).collect();
        m.add_le(LinExpr::sum(vars.clone()), 6);
        m.minimize(LinExpr::weighted_sum(
            vars.iter().enumerate().map(|(i, &v)| ((i as i64 * 13 % 11) - 5, v)),
        ));
        let s1 = solve(&m, SearchConfig::default());
        let s2 = solve(&m, SearchConfig::default());
        // Whole-solution equality: every field but wall clock, stats included.
        assert_eq!(s1, s2);
    }

    #[test]
    fn solution_equality_ignores_wall_clock() {
        let a = Solution {
            status: Status::Optimal,
            assignment: Some(vec![1]),
            objective: Some(1),
            nodes: 3,
            solve_ms: 0,
            stats: SolveStats::default(),
        };
        let b = Solution { solve_ms: 10_000, ..a.clone() };
        assert_eq!(a, b);
        let c = Solution { nodes: 4, ..a.clone() };
        assert_ne!(a, c);
    }

    #[test]
    fn both_engines_agree_node_for_node() {
        let mut m = CpModel::new();
        let vars: Vec<_> = (0..10).map(|i| m.bool_var(format!("b{i}"))).collect();
        for w in vars.windows(3) {
            m.add_le(LinExpr::sum(w.to_vec()), 2);
        }
        m.add_ge(LinExpr::sum(vars.clone()), 3);
        m.minimize(LinExpr::weighted_sum(
            vars.iter().enumerate().map(|(i, &v)| ((i as i64 * 7 % 5) - 2, v)),
        ));
        let inc = solve(&m, SearchConfig { validate: true, ..Default::default() });
        let reference = solve(
            &m,
            SearchConfig { engine: EngineKind::Reference, ..Default::default() },
        );
        assert_eq!(inc.status, reference.status);
        assert_eq!(inc.objective, reference.objective);
        assert_eq!(inc.assignment, reference.assignment);
        assert_eq!(inc.nodes, reference.nodes);
        assert_eq!(inc.stats.backtracks, reference.stats.backtracks);
        assert_eq!(inc.stats.peak_trail, reference.stats.peak_trail);
    }

    #[test]
    fn last_conflict_branching_still_reaches_the_optimum() {
        let mut m = CpModel::new();
        let vars: Vec<_> = (0..8).map(|i| m.int_var(0, 3, format!("x{i}"))).collect();
        for w in vars.windows(2) {
            m.add_le(LinExpr::sum(w.to_vec()), 4);
        }
        m.add_ge(LinExpr::sum(vars.clone()), 6);
        m.minimize(LinExpr::weighted_sum(
            vars.iter().enumerate().map(|(i, &v)| (i as i64 % 3 + 1, v)),
        ));
        let base = solve(&m, SearchConfig::default());
        for engine in [EngineKind::Incremental, EngineKind::Reference] {
            let lc = solve(
                &m,
                SearchConfig { last_conflict: true, engine, ..Default::default() },
            );
            assert_eq!(lc.status, Status::Optimal);
            assert_eq!(lc.objective, base.objective);
        }
    }

    #[test]
    fn stats_count_entailments_and_propagations() {
        let mut m = CpModel::new();
        let a = m.int_var(0, 10, "a");
        let b = m.int_var(0, 10, "b");
        m.add_le(LinExpr::new().add(1, a).add(1, b), 25); // entailed at the root
        m.add_ge(LinExpr::sum([a, b]), 2);
        m.minimize(LinExpr::sum([a, b]));
        let s = solve(&m, SearchConfig { validate: true, ..Default::default() });
        assert_eq!(s.status, Status::Optimal);
        assert_eq!(s.objective, Some(2));
        assert!(s.stats.entailments >= 1, "loose ≤ must be entailed: {:?}", s.stats);
        assert!(s.stats.propagations > 0);
        assert!(s.stats.peak_trail > 0);
    }
}
