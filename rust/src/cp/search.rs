//! Branch-and-bound search over a [`CpModel`].
//!
//! Depth-first search with trail-based backtracking:
//!   * presolve propagation at the root;
//!   * deterministic variable selection (smallest remaining domain, ties by
//!     index — keeps compile results reproducible run-to-run);
//!   * value ordering steered by the objective (try the value that pulls the
//!     objective down first);
//!   * objective-bound pruning against the incumbent;
//!   * node and wall-time limits with best-effort (incumbent) results, the
//!     behaviour the paper relies on when it trades schedule quality for
//!     compile time (Table II).

use std::time::Instant;

use super::model::{CpModel, Var};
use super::propagate::{expr_min, Domains, PropResult, Propagator, TrailEntry};

/// Search configuration.
#[derive(Debug, Clone)]
pub struct SearchConfig {
    /// Abort after this many explored nodes (None = unlimited).
    pub node_limit: Option<u64>,
    /// Abort after this wall-clock budget in milliseconds (None = unlimited).
    pub time_limit_ms: Option<u64>,
    /// Stop at the first feasible solution (ignore optimality).
    pub first_solution_only: bool,
    /// Warm-start hint: a full assignment (indexed by var index). If it
    /// satisfies the model it becomes the initial incumbent, so the search
    /// can only improve on it — and prunes against it from node one.
    pub hint: Option<Vec<i64>>,
}

impl Default for SearchConfig {
    fn default() -> Self {
        Self {
            node_limit: Some(2_000_000),
            time_limit_ms: Some(20_000),
            first_solution_only: false,
            hint: None,
        }
    }
}

impl SearchConfig {
    /// Warm-start from a prior [`Solution`]: folds its assignment into
    /// [`SearchConfig::hint`], so a compatible, still-feasible prior result
    /// becomes the incumbent at node one and the search is *anytime* — a
    /// node-budget expiry returns the seed (or something strictly better)
    /// instead of failing. A seed without an assignment, with the wrong
    /// arity, or violating the model is silently dropped by the hint
    /// validation in [`solve`] — warm-starting degrades to a cold search,
    /// never to a wrong answer.
    pub fn with_seed(mut self, seed: &Solution) -> Self {
        if let Some(a) = &seed.assignment {
            self.hint = Some(a.clone());
        }
        self
    }
}

/// Why the search returned.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Status {
    /// Proven optimal (or proven feasible with no objective).
    Optimal,
    /// A solution was found but the search hit a limit before proving
    /// optimality.
    Feasible,
    /// Proven infeasible.
    Infeasible,
    /// Limit hit before any solution was found.
    Unknown,
}

/// Search outcome.
#[derive(Debug, Clone)]
pub struct Solution {
    pub status: Status,
    /// Best assignment found (indexed by var index), if any.
    pub assignment: Option<Vec<i64>>,
    /// Objective of the best assignment.
    pub objective: Option<i64>,
    /// Explored node count.
    pub nodes: u64,
    /// Wall time of the solve in milliseconds.
    pub solve_ms: u64,
}

/// Why [`Solution::value`] could not produce a value.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ValueError {
    /// The search ended without any assignment (`Infeasible`/`Unknown`).
    NoSolution,
    /// The variable does not belong to the solved model: its index lies
    /// outside the assignment (e.g. a `Var` from a different `CpModel`).
    NoSuchVar {
        /// Index of the offending variable.
        index: usize,
        /// Number of variables in the solved model.
        num_vars: usize,
    },
}

impl std::fmt::Display for ValueError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ValueError::NoSolution => write!(f, "no solution: search found no assignment"),
            ValueError::NoSuchVar { index, num_vars } => write!(
                f,
                "variable index {index} is not in the solved model ({num_vars} vars)"
            ),
        }
    }
}

impl std::error::Error for ValueError {}

impl Solution {
    /// Value of a variable in the best assignment. Returns a structured
    /// error instead of panicking when there is no assignment or when `v`
    /// comes from a different model than the one solved.
    pub fn value(&self, v: Var) -> Result<i64, ValueError> {
        let a = self.assignment.as_ref().ok_or(ValueError::NoSolution)?;
        a.get(v.index()).copied().ok_or(ValueError::NoSuchVar {
            index: v.index(),
            num_vars: a.len(),
        })
    }

    /// True if a usable assignment exists.
    pub fn has_solution(&self) -> bool {
        self.assignment.is_some()
    }
}

struct SearchCtx<'m> {
    model: &'m CpModel,
    prop: Propagator,
    dom: Domains,
    trail: Vec<TrailEntry>,
    /// Objective terms (empty if satisfaction problem).
    obj_terms: Vec<(i64, Var)>,
    obj_const: i64,
    best: Option<(i64, Vec<i64>)>,
    nodes: u64,
    start: Instant,
    cfg: SearchConfig,
    limit_hit: bool,
}

impl<'m> SearchCtx<'m> {
    fn undo_to(&mut self, mark: usize) {
        while self.trail.len() > mark {
            match self.trail.pop().unwrap() {
                TrailEntry::Lb(v, old) => self.dom.lb[v.index()] = old,
                TrailEntry::Ub(v, old) => self.dom.ub[v.index()] = old,
            }
        }
    }

    fn limits_exceeded(&mut self) -> bool {
        if self.limit_hit {
            return true;
        }
        if let Some(n) = self.cfg.node_limit {
            if self.nodes >= n {
                self.limit_hit = true;
                return true;
            }
        }
        if let Some(ms) = self.cfg.time_limit_ms {
            // Check time only periodically — Instant::now is not free.
            if self.nodes % 256 == 0 && self.start.elapsed().as_millis() as u64 >= ms {
                self.limit_hit = true;
                return true;
            }
        }
        false
    }

    /// Pick the branching variable: unfixed var with the smallest domain,
    /// ties broken by index for determinism. Returns None if all fixed.
    fn select_var(&self) -> Option<Var> {
        let mut best: Option<(i64, usize)> = None;
        for i in 0..self.dom.lb.len() {
            let w = self.dom.ub[i] - self.dom.lb[i];
            if w > 0 {
                match best {
                    Some((bw, _)) if bw <= w => {}
                    _ => best = Some((w, i)),
                }
            }
        }
        best.map(|(_, i)| Var(i as u32))
    }

    /// Objective coefficient of `v` (0 if absent). Objective terms are
    /// normalized, so binary search applies.
    fn obj_coef(&self, v: Var) -> i64 {
        self.obj_terms
            .binary_search_by_key(&v, |&(_, var)| var)
            .map(|i| self.obj_terms[i].0)
            .unwrap_or(0)
    }

    fn dfs(&mut self) {
        self.nodes += 1;
        if self.limits_exceeded() {
            return;
        }

        // Objective-bound pruning.
        if let Some((best_obj, _)) = &self.best {
            let lb = expr_min(&self.obj_terms, self.obj_const, &self.dom);
            if lb >= *best_obj {
                return;
            }
        }

        let Some(v) = self.select_var() else {
            // All vars fixed ⇒ propagation already verified consistency.
            let assignment = self.dom.assignment();
            let obj = expr_min(&self.obj_terms, self.obj_const, &self.dom);
            debug_assert!(self.model.violated(&assignment).is_none());
            let better = match &self.best {
                Some((b, _)) => obj < *b,
                None => true,
            };
            if better {
                self.best = Some((obj, assignment));
            }
            return;
        };

        // Value ordering: if the objective rewards small values (coef ≥ 0)
        // try lb first, else ub first. Branch as x = bound vs x ≠ bound.
        let coef = self.obj_coef(v);
        let lb_first = coef >= 0;
        let (first_is_lb, second_is_lb) = (lb_first, !lb_first);
        for is_lb in [first_is_lb, second_is_lb] {
            if self.limit_hit {
                return;
            }
            // With an incumbent we still need to explore both branches.
            let mark = self.trail.len();
            let ok = if is_lb {
                let val = self.dom.lb(v);
                // x = lb branch: set ub := lb
                let old = self.dom.ub[v.index()];
                if old != val {
                    self.trail.push(TrailEntry::Ub(v, old));
                    self.dom.ub[v.index()] = val;
                }
                true
            } else {
                let val = self.dom.ub(v);
                let old = self.dom.lb[v.index()];
                if old != val {
                    self.trail.push(TrailEntry::Lb(v, old));
                    self.dom.lb[v.index()] = val;
                }
                true
            };
            if ok {
                let res = self
                    .prop
                    .propagate_from(self.model, &mut self.dom, &mut self.trail, v);
                if res == PropResult::Consistent {
                    self.dfs();
                    if self.cfg.first_solution_only && self.best.is_some() {
                        self.undo_to(mark);
                        return;
                    }
                }
            }
            self.undo_to(mark);

            // Second branch excludes the tried bound: x ≥ lb+1 (or ≤ ub-1).
            // Applied before the loop's second iteration via domain shrink.
            if is_lb == first_is_lb {
                let mark2 = self.trail.len();
                let feas = if first_is_lb {
                    let old = self.dom.lb[v.index()];
                    let nv = old + 1;
                    if nv > self.dom.ub(v) {
                        false
                    } else {
                        self.trail.push(TrailEntry::Lb(v, old));
                        self.dom.lb[v.index()] = nv;
                        true
                    }
                } else {
                    let old = self.dom.ub[v.index()];
                    let nv = old - 1;
                    if nv < self.dom.lb(v) {
                        false
                    } else {
                        self.trail.push(TrailEntry::Ub(v, old));
                        self.dom.ub[v.index()] = nv;
                        true
                    }
                };
                if !feas {
                    self.undo_to(mark2);
                    return; // domain exhausted; both branches done
                }
                let res = self
                    .prop
                    .propagate_from(self.model, &mut self.dom, &mut self.trail, v);
                if res == PropResult::Infeasible {
                    self.undo_to(mark2);
                    return;
                }
                // Recurse over the reduced domain instead of a literal
                // second value: gives binary-tree branching on ranges.
                self.dfs();
                self.undo_to(mark2);
                return;
            }
        }
    }
}

/// Solve `model` with the given configuration.
pub fn solve(model: &CpModel, cfg: SearchConfig) -> Solution {
    let start = Instant::now();
    let mut dom = Domains::from_model(model);
    let mut prop = Propagator::new(model);
    let mut trail = Vec::new();

    // Root presolve.
    if prop.propagate_all(model, &mut dom, &mut trail) == PropResult::Infeasible {
        return Solution {
            status: Status::Infeasible,
            assignment: None,
            objective: None,
            nodes: 0,
            solve_ms: start.elapsed().as_millis() as u64,
        };
    }

    let (obj_terms, obj_const) = match &model.objective {
        Some(o) => (o.terms.clone(), o.constant),
        None => (Vec::new(), 0),
    };
    let mut obj_terms = obj_terms;
    obj_terms.sort_by_key(|&(_, v)| v);

    // Warm start: adopt a valid hint as the initial incumbent.
    let initial_best = cfg.hint.as_ref().and_then(|h| {
        if h.len() == model.vars.len() && model.violated(h).is_none() {
            let obj = obj_const
                + obj_terms
                    .iter()
                    .map(|&(c, v)| c * h[v.index()])
                    .sum::<i64>();
            Some((obj, h.clone()))
        } else {
            None
        }
    });

    let mut ctx = SearchCtx {
        model,
        prop,
        dom,
        trail,
        obj_terms,
        obj_const,
        best: initial_best,
        nodes: 0,
        start,
        cfg,
        limit_hit: false,
    };
    ctx.dfs();

    let solve_ms = ctx.start.elapsed().as_millis() as u64;
    match ctx.best {
        Some((obj, assignment)) => Solution {
            status: if ctx.limit_hit { Status::Feasible } else { Status::Optimal },
            objective: Some(obj),
            assignment: Some(assignment),
            nodes: ctx.nodes,
            solve_ms,
        },
        None => Solution {
            status: if ctx.limit_hit { Status::Unknown } else { Status::Infeasible },
            objective: None,
            assignment: None,
            nodes: ctx.nodes,
            solve_ms,
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cp::model::LinExpr;

    #[test]
    fn optimal_simple_lp() {
        // min x + y  s.t. x + y >= 3, x,y in [0,5]
        let mut m = CpModel::new();
        let x = m.int_var(0, 5, "x");
        let y = m.int_var(0, 5, "y");
        m.add_ge(LinExpr::sum([x, y]), 3);
        m.minimize(LinExpr::sum([x, y]));
        let s = solve(&m, SearchConfig::default());
        assert_eq!(s.status, Status::Optimal);
        assert_eq!(s.objective, Some(3));
    }

    #[test]
    fn infeasible_model() {
        let mut m = CpModel::new();
        let x = m.bool_var("x");
        m.add_ge(LinExpr::var(x), 1);
        m.add_le(LinExpr::var(x), 0);
        let s = solve(&m, SearchConfig::default());
        assert_eq!(s.status, Status::Infeasible);
    }

    #[test]
    fn knapsack_optimal() {
        // max 6a+5b+4c st 2a+3b+4c <= 5 → min -(...)
        let mut m = CpModel::new();
        let a = m.bool_var("a");
        let b = m.bool_var("b");
        let c = m.bool_var("c");
        m.add_le(LinExpr::new().add(2, a).add(3, b).add(4, c), 5);
        m.minimize(LinExpr::new().add(-6, a).add(-5, b).add(-4, c));
        let s = solve(&m, SearchConfig::default());
        assert_eq!(s.status, Status::Optimal);
        assert_eq!(s.objective, Some(-11)); // a + b
        assert_eq!(s.value(a), Ok(1));
        assert_eq!(s.value(b), Ok(1));
        assert_eq!(s.value(c), Ok(0));
    }

    #[test]
    fn exactly_one_selection() {
        // min cost with exactly-one constraint: costs 7, 3, 9
        let mut m = CpModel::new();
        let v: Vec<_> = (0..3).map(|i| m.bool_var(format!("s{i}"))).collect();
        m.add_exactly_one(v.clone());
        m.minimize(LinExpr::weighted_sum([(7, v[0]), (3, v[1]), (9, v[2])]));
        let s = solve(&m, SearchConfig::default());
        assert_eq!(s.objective, Some(3));
        assert_eq!(s.value(v[1]), Ok(1));
    }

    #[test]
    fn satisfaction_without_objective() {
        let mut m = CpModel::new();
        let x = m.int_var(0, 9, "x");
        let y = m.int_var(0, 9, "y");
        m.add_eq(LinExpr::new().add(1, x).add(1, y), 9);
        m.add_eq(LinExpr::new().add(1, x).add(-1, y), 3);
        let s = solve(&m, SearchConfig::default());
        assert!(s.has_solution());
        assert_eq!(s.value(x), Ok(6));
        assert_eq!(s.value(y), Ok(3));
    }

    #[test]
    fn node_limit_returns_feasible_or_unknown() {
        let mut m = CpModel::new();
        let vars: Vec<_> = (0..30).map(|i| m.bool_var(format!("b{i}"))).collect();
        // Loose parity-ish constraints to make a big tree.
        for w in vars.windows(2) {
            m.add_le(LinExpr::sum(w.to_vec()), 1);
        }
        m.minimize(LinExpr::weighted_sum(
            vars.iter().enumerate().map(|(i, &v)| (-(i as i64 % 7 + 1), v)),
        ));
        let s = solve(
            &m,
            SearchConfig { node_limit: Some(50), ..Default::default() },
        );
        assert!(matches!(s.status, Status::Feasible | Status::Unknown | Status::Optimal));
    }

    #[test]
    fn value_returns_structured_errors_instead_of_panicking() {
        // Infeasible model: no assignment at all.
        let mut m = CpModel::new();
        let x = m.bool_var("x");
        m.add_ge(LinExpr::var(x), 1);
        m.add_le(LinExpr::var(x), 0);
        let s = solve(&m, SearchConfig::default());
        assert_eq!(s.value(x), Err(ValueError::NoSolution));

        // Feasible model, but a Var from a *bigger* model: out of range.
        let mut small = CpModel::new();
        let a = small.bool_var("a");
        small.minimize(LinExpr::var(a));
        let s = solve(&small, SearchConfig::default());
        assert_eq!(s.value(a), Ok(0));
        let mut big = CpModel::new();
        let _ = big.bool_var("p");
        let q = big.bool_var("q");
        assert_eq!(
            s.value(q),
            Err(ValueError::NoSuchVar { index: 1, num_vars: 1 })
        );
        let msg = s.value(q).unwrap_err().to_string();
        assert!(msg.contains("index 1"), "{msg}");
    }

    #[test]
    fn seeded_search_adopts_incumbent_and_stays_anytime() {
        // min 3a+2b+c  s.t. a+b+c >= 2 — optimum is b=c=1 → 3.
        let mut m = CpModel::new();
        let a = m.bool_var("a");
        let b = m.bool_var("b");
        let c = m.bool_var("c");
        m.add_ge(LinExpr::sum([a, b, c]), 2);
        m.minimize(LinExpr::weighted_sum([(3, a), (2, b), (1, c)]));

        // A feasible but suboptimal prior solution (a=b=1 → 5).
        let prior = Solution {
            status: Status::Feasible,
            assignment: Some(vec![1, 1, 0]),
            objective: Some(5),
            nodes: 0,
            solve_ms: 0,
        };

        // Zero-node budget: the anytime search returns the seed itself.
        let cfg = SearchConfig {
            node_limit: Some(0),
            ..Default::default()
        }
        .with_seed(&prior);
        let s = solve(&m, cfg);
        assert_eq!(s.status, Status::Feasible);
        assert_eq!(s.objective, Some(5));

        // Unlimited budget: the seed never blocks reaching the optimum.
        let s = solve(&m, SearchConfig::default().with_seed(&prior));
        assert_eq!(s.status, Status::Optimal);
        assert_eq!(s.objective, Some(3));
    }

    #[test]
    fn invalid_seed_degrades_to_cold_search() {
        let mut m = CpModel::new();
        let x = m.int_var(0, 5, "x");
        m.add_ge(LinExpr::var(x), 2);
        m.minimize(LinExpr::var(x));
        // Wrong arity and constraint-violating seeds are both dropped.
        for bad in [vec![0i64, 0], vec![0]] {
            let seed = Solution {
                status: Status::Feasible,
                assignment: Some(bad),
                objective: None,
                nodes: 0,
                solve_ms: 0,
            };
            let s = solve(&m, SearchConfig::default().with_seed(&seed));
            assert_eq!(s.status, Status::Optimal);
            assert_eq!(s.objective, Some(2));
        }
    }

    #[test]
    fn deterministic_across_runs() {
        let mut m = CpModel::new();
        let vars: Vec<_> = (0..12).map(|i| m.bool_var(format!("b{i}"))).collect();
        m.add_le(LinExpr::sum(vars.clone()), 6);
        m.minimize(LinExpr::weighted_sum(
            vars.iter().enumerate().map(|(i, &v)| ((i as i64 * 13 % 11) - 5, v)),
        ));
        let s1 = solve(&m, SearchConfig::default());
        let s2 = solve(&m, SearchConfig::default());
        assert_eq!(s1.assignment, s2.assignment);
        assert_eq!(s1.objective, s2.objective);
    }
}
