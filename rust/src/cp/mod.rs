//! Constraint-programming substrate.
//!
//! The paper's compiler mid-end formulates tiling+fusion (Sec. IV-C),
//! scheduling (Sec. IV-B) and memory allocation (Sec. IV-D) as constraint
//! programs. The authors use a commercial CP stack; this module is the
//! from-scratch equivalent: a bounded-integer linear CP with bounds
//! propagation and deterministic branch-and-bound, plus node/time limits so
//! the partitioning trade-off of Table II can be reproduced faithfully.
//!
//! The propagation hot path is the incremental cached-activity engine in
//! [`propagate`]; the original recompute-per-visit engine lives on in
//! [`reference`] as a differential oracle (select it with
//! [`EngineKind::Reference`]). Every solve reports deterministic
//! [`SolveStats`]; the design and determinism contract are documented in
//! `docs/solver.md`.

pub mod model;
pub mod propagate;
pub mod reference;
pub mod search;

pub use model::{Cmp, CpModel, LinExpr, Var};
pub use search::{
    solve, EngineKind, SearchConfig, Solution, SolveStats, Status, ValueError,
};

#[cfg(test)]
mod integration_tests {
    use super::*;

    /// A miniature version of the paper's scheduling structure: tiles with
    /// persistency + dependency constraints over timesteps, minimizing a
    /// latency-like objective. Exercises model + propagate + search together.
    #[test]
    fn mini_schedule_round_trip() {
        let mut m = CpModel::new();
        let t_steps = 4usize;
        // Two tiles: tile 1 depends on tile 0 being "in TCM".
        let tcm0: Vec<Var> = (0..t_steps).map(|t| m.bool_var(format!("tcm0_{t}"))).collect();
        let tcm1: Vec<Var> = (0..t_steps).map(|t| m.bool_var(format!("tcm1_{t}"))).collect();
        let cmp0: Vec<Var> = (0..t_steps).map(|t| m.bool_var(format!("cmp0_{t}"))).collect();
        let cmp1: Vec<Var> = (0..t_steps).map(|t| m.bool_var(format!("cmp1_{t}"))).collect();

        // Persistency (Eq. 1): TCM(j,t) requires TCM(j,t-1) or compute(j,t-1).
        for t in 1..t_steps {
            m.add_ge(
                LinExpr::new()
                    .add(1, tcm0[t - 1])
                    .add(1, cmp0[t - 1])
                    .add(-1, tcm0[t]),
                0,
            );
            m.add_ge(
                LinExpr::new()
                    .add(1, tcm1[t - 1])
                    .add(1, cmp1[t - 1])
                    .add(-1, tcm1[t]),
                0,
            );
        }
        // t=0: nothing resident yet.
        m.add_le(LinExpr::var(tcm0[0]), 0);
        m.add_le(LinExpr::var(tcm1[0]), 0);

        // Dependency (Eq. 2): compute(1,t) ≤ TCM(0,t).
        for t in 0..t_steps {
            m.add_le(LinExpr::new().add(1, cmp1[t]).add(-1, tcm0[t]), 0);
        }
        // Each tile computed exactly once.
        m.add_exactly_one(cmp0.clone());
        m.add_exactly_one(cmp1.clone());
        // One compute per timestep.
        for t in 0..t_steps {
            m.add_le(LinExpr::new().add(1, cmp0[t]).add(1, cmp1[t]), 1);
        }

        // Objective: finish early — penalize late computes.
        let mut obj = LinExpr::new();
        for t in 0..t_steps {
            obj.push(t as i64 + 1, cmp0[t]);
            obj.push(t as i64 + 1, cmp1[t]);
        }
        m.minimize(obj);

        let s = solve(&m, SearchConfig::default());
        assert_eq!(s.status, Status::Optimal);
        // Optimal: compute tile0 at t=0, tile1 at t=1 (after tile0 resident).
        assert_eq!(s.value(cmp0[0]), Ok(1));
        assert_eq!(s.value(cmp1[1]), Ok(1));
        assert_eq!(s.objective, Some(1 + 2));
        // Solution must satisfy the full model.
        assert!(m.violated(s.assignment.as_ref().unwrap()).is_none());
    }

    /// Max/min helper encodings used by the memory constraints (Eq. 4–6).
    #[test]
    fn max_min_bank_encoding() {
        let mut m = CpModel::new();
        // Two tiles active with bank ranges [2,3] and [5,6]; tensor memory
        // footprint must be max_hi - min_lo + 1 = 5.
        let hi = m.int_var(0, 10, "hi");
        let lo = m.int_var(0, 10, "lo");
        m.add_max_ge(hi, [LinExpr::constant(3), LinExpr::constant(6)]);
        m.add_min_le(lo, [LinExpr::constant(2), LinExpr::constant(5)]);
        // mem = hi - lo + 1, minimized
        m.minimize(LinExpr::new().add(1, hi).add(-1, lo));
        let s = solve(&m, SearchConfig::default());
        assert_eq!(s.status, Status::Optimal);
        assert_eq!(s.value(hi), Ok(6));
        assert_eq!(s.value(lo), Ok(2));
    }
}
