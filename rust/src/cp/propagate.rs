//! Incremental bounds propagation for linear constraints.
//!
//! Classic activity-based bound tightening: for `Σ aᵢxᵢ ≤ b`, the minimum
//! activity of all terms but one bounds the remaining term, which tightens
//! that variable's domain. Runs to fixpoint over a deduped priority queue;
//! equalities propagate in both directions. Used both at the root (presolve)
//! and at every node of the branch-and-bound search.
//!
//! Unlike the original recompute-per-visit engine (preserved as the
//! differential oracle in [`crate::cp::reference`]), this engine keeps
//! **cached activity bounds**: per constraint, `min_act = Σ min(aᵢxᵢ)` and
//! `max_act = Σ max(aᵢxᵢ)` are maintained in O(watchers) per bound change and
//! restored exactly — integer deltas, no drift — on trail undo. On top of the
//! caches sit **entailment watching** (a constraint whose cached activity
//! already proves it satisfied for every assignment in the current box can
//! never tighten anything deeper in the subtree, so it is unwatched until
//! backtrack) and a **priority queue** (constraints with ≤1 unfixed variable
//! first — those fix a variable outright — with a deterministic index
//! tie-break). Queue order cannot affect results: every constraint is
//! re-enqueued until it reaches its own closure (equalities included, whose
//! `≤`/`≥` passes can feed each other), so each run converges to the unique
//! greatest common fixpoint of the sound, monotone per-constraint tighteners
//! regardless of visit order. The determinism/equivalence contract is spelled
//! out in `docs/solver.md`.

use std::collections::BTreeSet;

use super::model::{Cmp, CpModel, Var};

/// Mutable view of variable domains during search. Bounds are trailed by the
/// search layer for backtracking.
#[derive(Debug, Clone)]
pub struct Domains {
    pub(crate) lb: Vec<i64>,
    pub(crate) ub: Vec<i64>,
}

impl Domains {
    /// Initial domains from the model's declared variable bounds.
    pub fn from_model(model: &CpModel) -> Self {
        Self {
            lb: model.vars.iter().map(|v| v.lb).collect(),
            ub: model.vars.iter().map(|v| v.ub).collect(),
        }
    }

    #[inline]
    pub fn lb(&self, v: Var) -> i64 {
        self.lb[v.index()]
    }

    #[inline]
    pub fn ub(&self, v: Var) -> i64 {
        self.ub[v.index()]
    }

    #[inline]
    pub fn is_fixed(&self, v: Var) -> bool {
        self.lb[v.index()] == self.ub[v.index()]
    }

    /// Every variable fixed?
    pub fn all_fixed(&self) -> bool {
        self.lb.iter().zip(&self.ub).all(|(l, u)| l == u)
    }

    /// Extract the (unique) assignment of fully-fixed domains.
    pub fn assignment(&self) -> Vec<i64> {
        debug_assert!(self.all_fixed());
        self.lb.clone()
    }
}

/// One reversible propagation event, recorded so the search can undo it on
/// backtrack. Bound entries carry the *old* bound; the activity-cache deltas
/// they imply are recomputed exactly (same integer products) on undo, so the
/// trail itself stays as small as the original two-variant design.
#[derive(Debug, Clone, Copy)]
pub enum TrailEntry {
    /// Variable's lower bound was raised from `old`.
    Lb(Var, i64),
    /// Variable's upper bound was lowered from `old`.
    Ub(Var, i64),
    /// Constraint was detected entailed and unwatched; re-watched on undo.
    Entailed(u32),
}

/// Result of a propagation round.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PropResult {
    /// Fixpoint reached, domains consistent.
    Consistent,
    /// Some domain emptied — the current node is infeasible.
    Infeasible,
}

/// Propagation-layer event counters, folded into
/// [`SolveStats`](crate::cp::SolveStats) by the search layer.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PropCounters {
    /// Constraint visits (queue pops that reached the tightening code).
    pub propagations: u64,
    /// Successful bound changes (lb raised or ub lowered).
    pub tightenings: u64,
    /// Constraints detected entailed and unwatched.
    pub entailments: u64,
}

/// Sentinel for "no constraint currently being visited" (branch decisions).
const NO_EXCLUDE: u32 = u32::MAX;

/// The incremental propagation engine. Owns every domain mutation (branch
/// decisions included) so the cached activities, unfixed-variable counts and
/// entailment flags stay consistent with the trail at all times.
pub struct Propagator {
    /// For each var, the (constraint index, coefficient) pairs that mention it.
    watch: Vec<Vec<(u32, i64)>>,
    /// Cached `Σ term_min` per constraint under the current domains.
    min_act: Vec<i64>,
    /// Cached `Σ term_max` per constraint under the current domains.
    max_act: Vec<i64>,
    /// Number of watch entries (terms) of each constraint whose var is unfixed.
    unfixed: Vec<u32>,
    /// Entailed (unwatched) flags; set via the trail, cleared on undo.
    entailed: Vec<bool>,
    /// Pending constraints as (priority, index): priority 0 when at most one
    /// variable is unfixed (the visit can fix it outright), else 1. The
    /// priority is assessed at insertion time; `BTreeSet` iteration gives the
    /// deterministic (priority, index) pop order.
    queue: BTreeSet<(u8, u32)>,
    /// Dedup flags for the queue.
    in_queue: Vec<bool>,
    /// Event counters for the [`SolveStats`](crate::cp::SolveStats) layer.
    pub counters: PropCounters,
}

impl Propagator {
    /// Build the watch lists and activity caches for a model. The caches are
    /// (re)synchronized to the actual domains in [`Propagator::propagate_all`],
    /// which must be the first call on any fresh `Domains`.
    pub fn new(model: &CpModel) -> Self {
        let mut watch = vec![Vec::new(); model.vars.len()];
        for (ci, c) in model.cons.iter().enumerate() {
            for &(a, v) in &c.terms {
                watch[v.index()].push((ci as u32, a));
            }
        }
        let n = model.cons.len();
        Self {
            watch,
            min_act: vec![0; n],
            max_act: vec![0; n],
            unfixed: vec![0; n],
            entailed: vec![false; n],
            queue: BTreeSet::new(),
            in_queue: vec![false; n],
            counters: PropCounters::default(),
        }
    }

    #[inline]
    fn prio(&self, ci: u32) -> u8 {
        u8::from(self.unfixed[ci as usize] > 1)
    }

    #[inline]
    fn enqueue(&mut self, ci: u32) {
        if !self.in_queue[ci as usize] && !self.entailed[ci as usize] {
            self.in_queue[ci as usize] = true;
            self.queue.insert((self.prio(ci), ci));
        }
    }

    fn clear_queue(&mut self) {
        while let Some((_, ci)) = self.queue.pop_first() {
            self.in_queue[ci as usize] = false;
        }
    }

    /// Raise `v`'s lower bound to `new_lb` (no-op unless it tightens). Trails
    /// the change, updates every watcher's cached activities and unfixed
    /// count, and enqueues watchers other than `exclude`. Returns false when
    /// the domain empties.
    fn set_lb(
        &mut self,
        v: Var,
        new_lb: i64,
        dom: &mut Domains,
        trail: &mut Vec<TrailEntry>,
        exclude: u32,
    ) -> bool {
        let i = v.index();
        let old = dom.lb[i];
        if new_lb <= old {
            return true;
        }
        trail.push(TrailEntry::Lb(v, old));
        dom.lb[i] = new_lb;
        self.counters.tightenings += 1;
        let was_fixed = old == dom.ub[i];
        let now_fixed = new_lb == dom.ub[i];
        let delta = new_lb - old;
        for k in 0..self.watch[i].len() {
            let (cj, c) = self.watch[i][k];
            // lb moved: the bound-side term of min (c ≥ 0) or max (c < 0).
            if c >= 0 {
                self.min_act[cj as usize] += c * delta;
            } else {
                self.max_act[cj as usize] += c * delta;
            }
            if was_fixed != now_fixed {
                if now_fixed {
                    self.unfixed[cj as usize] -= 1;
                } else {
                    self.unfixed[cj as usize] += 1;
                }
            }
            if cj != exclude {
                self.enqueue(cj);
            }
        }
        dom.ub[i] >= new_lb
    }

    /// Lower `v`'s upper bound to `new_ub`; mirror of [`Propagator::set_lb`].
    fn set_ub(
        &mut self,
        v: Var,
        new_ub: i64,
        dom: &mut Domains,
        trail: &mut Vec<TrailEntry>,
        exclude: u32,
    ) -> bool {
        let i = v.index();
        let old = dom.ub[i];
        if new_ub >= old {
            return true;
        }
        trail.push(TrailEntry::Ub(v, old));
        dom.ub[i] = new_ub;
        self.counters.tightenings += 1;
        let was_fixed = old == dom.lb[i];
        let now_fixed = new_ub == dom.lb[i];
        let delta = new_ub - old;
        for k in 0..self.watch[i].len() {
            let (cj, c) = self.watch[i][k];
            if c >= 0 {
                self.max_act[cj as usize] += c * delta;
            } else {
                self.min_act[cj as usize] += c * delta;
            }
            if was_fixed != now_fixed {
                if now_fixed {
                    self.unfixed[cj as usize] -= 1;
                } else {
                    self.unfixed[cj as usize] += 1;
                }
            }
            if cj != exclude {
                self.enqueue(cj);
            }
        }
        dom.lb[i] >= new_ub
    }

    /// Branch decision `x = lb` (or the domain-shrink `x ≥ lb+1`): raise the
    /// lower bound through the engine so caches and queue stay consistent.
    pub fn branch_lb(
        &mut self,
        v: Var,
        new_lb: i64,
        dom: &mut Domains,
        trail: &mut Vec<TrailEntry>,
    ) -> bool {
        self.set_lb(v, new_lb, dom, trail, NO_EXCLUDE)
    }

    /// Branch decision `x = ub` (or the domain-shrink `x ≤ ub-1`).
    pub fn branch_ub(
        &mut self,
        v: Var,
        new_ub: i64,
        dom: &mut Domains,
        trail: &mut Vec<TrailEntry>,
    ) -> bool {
        self.set_ub(v, new_ub, dom, trail, NO_EXCLUDE)
    }

    /// Undo every trailed event past `mark`, restoring domains, cached
    /// activities (exact integer deltas — the same products that were added
    /// are subtracted), unfixed counts and entailment flags.
    pub fn undo_to(&mut self, dom: &mut Domains, trail: &mut Vec<TrailEntry>, mark: usize) {
        debug_assert!(self.queue.is_empty(), "undo with a non-empty queue");
        while trail.len() > mark {
            match trail.pop().unwrap() {
                TrailEntry::Lb(v, old) => {
                    let i = v.index();
                    let cur = dom.lb[i];
                    dom.lb[i] = old;
                    let was_fixed = cur == dom.ub[i];
                    let now_fixed = old == dom.ub[i];
                    let delta = old - cur;
                    for k in 0..self.watch[i].len() {
                        let (cj, c) = self.watch[i][k];
                        if c >= 0 {
                            self.min_act[cj as usize] += c * delta;
                        } else {
                            self.max_act[cj as usize] += c * delta;
                        }
                        if was_fixed != now_fixed {
                            if now_fixed {
                                self.unfixed[cj as usize] -= 1;
                            } else {
                                self.unfixed[cj as usize] += 1;
                            }
                        }
                    }
                }
                TrailEntry::Ub(v, old) => {
                    let i = v.index();
                    let cur = dom.ub[i];
                    dom.ub[i] = old;
                    let was_fixed = cur == dom.lb[i];
                    let now_fixed = old == dom.lb[i];
                    let delta = old - cur;
                    for k in 0..self.watch[i].len() {
                        let (cj, c) = self.watch[i][k];
                        if c >= 0 {
                            self.max_act[cj as usize] += c * delta;
                        } else {
                            self.min_act[cj as usize] += c * delta;
                        }
                        if was_fixed != now_fixed {
                            if now_fixed {
                                self.unfixed[cj as usize] -= 1;
                            } else {
                                self.unfixed[cj as usize] += 1;
                            }
                        }
                    }
                }
                TrailEntry::Entailed(ci) => self.entailed[ci as usize] = false,
            }
        }
    }

    /// Propagate all constraints to fixpoint (root call). Synchronizes the
    /// activity caches with `dom` first, so the engine may be paired with any
    /// fresh `Domains` (not just the model's declared bounds).
    pub fn propagate_all(
        &mut self,
        model: &CpModel,
        dom: &mut Domains,
        trail: &mut Vec<TrailEntry>,
    ) -> PropResult {
        self.clear_queue();
        for (ci, con) in model.cons.iter().enumerate() {
            let mut mn = 0i64;
            let mut mx = 0i64;
            let mut uf = 0u32;
            for &(c, v) in &con.terms {
                mn += term_min(c, dom.lb(v), dom.ub(v));
                mx += term_max(c, dom.lb(v), dom.ub(v));
                uf += u32::from(!dom.is_fixed(v));
            }
            self.min_act[ci] = mn;
            self.max_act[ci] = mx;
            self.unfixed[ci] = uf;
            self.entailed[ci] = false;
        }
        for ci in 0..model.cons.len() as u32 {
            self.enqueue(ci);
        }
        self.run(model, dom, trail)
    }

    /// Drain the queue to fixpoint. Branch decisions enqueue the affected
    /// watchers themselves, so a node propagation is `branch_*` + `run`.
    pub fn run(
        &mut self,
        model: &CpModel,
        dom: &mut Domains,
        trail: &mut Vec<TrailEntry>,
    ) -> PropResult {
        while let Some((_, ci)) = self.queue.pop_first() {
            self.in_queue[ci as usize] = false;
            if self.entailed[ci as usize] {
                continue;
            }
            if self.visit(model, dom, trail, ci) == PropResult::Infeasible {
                // Leave the queue empty so backtracking can proceed; the
                // unwound node re-enqueues nothing.
                self.clear_queue();
                return PropResult::Infeasible;
            }
        }
        PropResult::Consistent
    }

    /// Revisit one constraint: cached-activity feasibility and entailment
    /// checks, then the same per-term tightening arithmetic as the reference
    /// engine with `min_act` read from the cache instead of recomputed.
    fn visit(
        &mut self,
        model: &CpModel,
        dom: &mut Domains,
        trail: &mut Vec<TrailEntry>,
        ci: u32,
    ) -> PropResult {
        let con = &model.cons[ci as usize];
        self.counters.propagations += 1;
        let (min_act, max_act) = (self.min_act[ci as usize], self.max_act[ci as usize]);

        // Feasibility straight from the caches (the old engine derived the
        // same facts by recomputing the activity per visit).
        let infeasible = match con.cmp {
            Cmp::Le => min_act > con.rhs,
            Cmp::Ge => max_act < con.rhs,
            Cmp::Eq => min_act > con.rhs || max_act < con.rhs,
        };
        if infeasible {
            return PropResult::Infeasible;
        }

        // Entailment: satisfied for EVERY assignment in the current box ⇒ no
        // tightening possible here or in any descendant node. Unwatch until
        // backtrack (enqueue skips flagged constraints).
        let entailed = match con.cmp {
            Cmp::Le => max_act <= con.rhs,
            Cmp::Ge => min_act >= con.rhs,
            Cmp::Eq => min_act == con.rhs && max_act == con.rhs,
        };
        if entailed {
            self.entailed[ci as usize] = true;
            trail.push(TrailEntry::Entailed(ci));
            self.counters.entailments += 1;
            return PropResult::Consistent;
        }

        let (do_le, do_ge) = match con.cmp {
            Cmp::Le => (true, false),
            Cmp::Ge => (false, true),
            Cmp::Eq => (true, true),
        };
        // `≤` pass: cap each term by rhs minus the other terms' minimum.
        // `min_act` stays valid throughout the pass — the pass only lowers
        // ubs of positive terms and raises lbs of negative terms, neither of
        // which moves any term's minimum. An equality's `≥` pass below CAN
        // move it, which is why changed Eq constraints re-enqueue themselves
        // (`exclude` only suppresses the self-wakeup, never other watchers):
        // both engines share that closure rule, making the fixpoint
        // independent of queue order.
        if do_le {
            for &(c, v) in &con.terms {
                let cap = con.rhs - (min_act - term_min(c, dom.lb(v), dom.ub(v)));
                let ok = if c > 0 {
                    self.set_ub(v, cap.div_euclid(c), dom, trail, ci)
                } else if c < 0 {
                    self.set_lb(v, div_ceil(cap, c), dom, trail, ci)
                } else {
                    true
                };
                if !ok {
                    return PropResult::Infeasible;
                }
            }
        }
        // `≥` pass via the negated view: Σ aᵢxᵢ ≥ b ⇔ Σ (-aᵢ)xᵢ ≤ -b, whose
        // minimum activity is -max_act. Re-read the cache: an Eq's `≤` pass
        // above may have tightened negative-coefficient lbs, and the cache
        // already reflects that (the old engine recomputed here).
        if do_ge {
            let min_act_neg = -self.max_act[ci as usize];
            let rhs_neg = -con.rhs;
            if min_act_neg > rhs_neg {
                return PropResult::Infeasible;
            }
            for &(c, v) in &con.terms {
                let nc = -c;
                let cap = rhs_neg - (min_act_neg - term_min(nc, dom.lb(v), dom.ub(v)));
                let ok = if nc > 0 {
                    self.set_ub(v, cap.div_euclid(nc), dom, trail, ci)
                } else if nc < 0 {
                    self.set_lb(v, div_ceil(cap, nc), dom, trail, ci)
                } else {
                    true
                };
                if !ok {
                    return PropResult::Infeasible;
                }
            }
        }
        // Self-requeue equalities whose own visit moved a bound: the two
        // passes feed each other, so one visit may not reach the constraint's
        // closure. (`set_*` excluded `ci`; the wakeup happens here instead so
        // an unchanged constraint is not revisited.)
        if con.cmp == Cmp::Eq
            && (self.min_act[ci as usize], self.max_act[ci as usize]) != (min_act, max_act)
        {
            self.enqueue(ci);
        }
        PropResult::Consistent
    }

    /// Test/validate-mode oracle: recompute every cache from scratch and
    /// panic on any divergence. Called by the search layer after each undo
    /// when [`SearchConfig::validate`](crate::cp::SearchConfig::validate) is
    /// set; O(model) per call, never enabled on production paths.
    pub fn check_invariants(&self, model: &CpModel, dom: &Domains) {
        assert!(self.queue.is_empty(), "invariant: queue not drained");
        for (ci, con) in model.cons.iter().enumerate() {
            let mut mn = 0i64;
            let mut mx = 0i64;
            let mut uf = 0u32;
            for &(c, v) in &con.terms {
                mn += term_min(c, dom.lb(v), dom.ub(v));
                mx += term_max(c, dom.lb(v), dom.ub(v));
                uf += u32::from(!dom.is_fixed(v));
            }
            assert_eq!(
                (self.min_act[ci], self.max_act[ci]),
                (mn, mx),
                "invariant: stale activity cache for constraint {ci} ({:?})",
                con.name
            );
            assert_eq!(
                self.unfixed[ci], uf,
                "invariant: stale unfixed count for constraint {ci}"
            );
            if self.entailed[ci] {
                let holds = match con.cmp {
                    Cmp::Le => mx <= con.rhs,
                    Cmp::Ge => mn >= con.rhs,
                    Cmp::Eq => mn == con.rhs && mx == con.rhs,
                };
                assert!(holds, "invariant: entailed flag on unentailed constraint {ci}");
            }
        }
    }
}

#[inline]
pub(crate) fn term_min(c: i64, lb: i64, ub: i64) -> i64 {
    if c >= 0 {
        c * lb
    } else {
        c * ub
    }
}

#[inline]
pub(crate) fn term_max(c: i64, lb: i64, ub: i64) -> i64 {
    if c >= 0 {
        c * ub
    } else {
        c * lb
    }
}

/// Ceiling division for possibly-negative divisor: smallest x with d*x ≤ cap
/// when d < 0 is x = ceil(cap/d).
#[inline]
pub(crate) fn div_ceil(cap: i64, d: i64) -> i64 {
    debug_assert!(d != 0);
    let q = cap / d;
    if cap % d != 0 && ((cap < 0) == (d < 0)) {
        q + 1
    } else {
        q
    }
}

/// Minimum possible value of a linear expression under current domains —
/// the objective lower bound used for pruning.
pub fn expr_min(terms: &[(i64, Var)], constant: i64, dom: &Domains) -> i64 {
    constant
        + terms
            .iter()
            .map(|&(c, v)| term_min(c, dom.lb(v), dom.ub(v)))
            .sum::<i64>()
}

/// Maximum possible value of a linear expression under current domains.
pub fn expr_max(terms: &[(i64, Var)], constant: i64, dom: &Domains) -> i64 {
    constant
        + terms
            .iter()
            .map(|&(c, v)| term_max(c, dom.lb(v), dom.ub(v)))
            .sum::<i64>()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cp::model::LinExpr;

    fn prop(model: &CpModel) -> (Domains, PropResult) {
        let mut dom = Domains::from_model(model);
        let mut p = Propagator::new(model);
        let mut trail = Vec::new();
        let r = p.propagate_all(model, &mut dom, &mut trail);
        if r == PropResult::Consistent {
            p.check_invariants(model, &dom);
        }
        (dom, r)
    }

    #[test]
    fn le_tightens_upper_bounds() {
        let mut m = CpModel::new();
        let a = m.int_var(0, 10, "a");
        let b = m.int_var(0, 10, "b");
        m.add_le(LinExpr::new().add(1, a).add(1, b), 4);
        let (dom, r) = prop(&m);
        assert_eq!(r, PropResult::Consistent);
        assert_eq!(dom.ub(a), 4);
        assert_eq!(dom.ub(b), 4);
    }

    #[test]
    fn eq_fixes_when_forced() {
        let mut m = CpModel::new();
        let a = m.int_var(0, 10, "a");
        let b = m.int_var(3, 3, "b");
        m.add_eq(LinExpr::new().add(1, a).add(1, b), 5);
        let (dom, r) = prop(&m);
        assert_eq!(r, PropResult::Consistent);
        assert_eq!((dom.lb(a), dom.ub(a)), (2, 2));
    }

    #[test]
    fn detects_infeasible() {
        let mut m = CpModel::new();
        let a = m.int_var(0, 1, "a");
        let b = m.int_var(0, 1, "b");
        m.add_ge(LinExpr::new().add(1, a).add(1, b), 3);
        let (_, r) = prop(&m);
        assert_eq!(r, PropResult::Infeasible);
    }

    #[test]
    fn negative_coefficients() {
        let mut m = CpModel::new();
        let a = m.int_var(0, 10, "a");
        let b = m.int_var(0, 10, "b");
        // a - b ≤ -5  ⇒  a ≤ b - 5 ⇒ a ≤ 5, b ≥ 5
        m.add_le(LinExpr::new().add(1, a).add(-1, b), -5);
        let (dom, r) = prop(&m);
        assert_eq!(r, PropResult::Consistent);
        assert_eq!(dom.ub(a), 5);
        assert_eq!(dom.lb(b), 5);
    }

    #[test]
    fn implication_chain_propagates() {
        let mut m = CpModel::new();
        let a = m.bool_var("a");
        let b = m.bool_var("b");
        let c = m.bool_var("c");
        m.add_implication(a, b);
        m.add_implication(b, c);
        m.add_ge(LinExpr::var(a), 1); // a = 1
        let (dom, r) = prop(&m);
        assert_eq!(r, PropResult::Consistent);
        assert_eq!(dom.lb(b), 1);
        assert_eq!(dom.lb(c), 1);
    }

    #[test]
    fn expr_min_max() {
        let mut m = CpModel::new();
        let a = m.int_var(1, 3, "a");
        let b = m.int_var(-2, 2, "b");
        let dom = Domains::from_model(&m);
        let terms = [(2i64, a), (-1i64, b)];
        assert_eq!(expr_min(&terms, 0, &dom), 2 * 1 - 2);
        assert_eq!(expr_max(&terms, 0, &dom), 2 * 3 + 2);
    }

    #[test]
    fn div_ceil_signs() {
        assert_eq!(div_ceil(7, -2), -3); // smallest x with -2x ≤ 7 → x ≥ -3.5 → -3
        assert_eq!(div_ceil(-7, -2), 4); // -2x ≤ -7 → x ≥ 3.5 → 4
        assert_eq!(div_ceil(6, -3), -2);
        assert_eq!(div_ceil(-6, -3), 2);
    }

    #[test]
    fn entailed_constraint_is_unwatched_and_rewatched_on_undo() {
        let mut m = CpModel::new();
        let a = m.int_var(0, 10, "a");
        let b = m.int_var(0, 10, "b");
        m.add_le(LinExpr::new().add(1, a).add(1, b), 25); // loose: max_act 20 ≤ 25
        let mut dom = Domains::from_model(&m);
        let mut p = Propagator::new(&m);
        let mut trail = Vec::new();
        assert_eq!(p.propagate_all(&m, &mut dom, &mut trail), PropResult::Consistent);
        assert_eq!(p.counters.entailments, 1);
        assert!(p.entailed[0]);
        assert!(matches!(trail.last(), Some(TrailEntry::Entailed(0))));
        p.check_invariants(&m, &dom);
        // Undo rewinds the flag.
        p.undo_to(&mut dom, &mut trail, 0);
        assert!(!p.entailed[0]);
        p.check_invariants(&m, &dom);
    }

    #[test]
    fn caches_track_branch_and_undo_exactly() {
        let mut m = CpModel::new();
        let a = m.int_var(0, 10, "a");
        let b = m.int_var(0, 10, "b");
        let c = m.int_var(-5, 5, "c");
        m.add_le(LinExpr::new().add(2, a).add(3, b).add(-1, c), 21);
        m.add_ge(LinExpr::new().add(1, a).add(1, b).add(1, c), 2);
        let mut dom = Domains::from_model(&m);
        let mut p = Propagator::new(&m);
        let mut trail = Vec::new();
        assert_eq!(p.propagate_all(&m, &mut dom, &mut trail), PropResult::Consistent);
        let mark = trail.len();
        // Branch a = 4, propagate, then unwind: caches must be bit-exact.
        assert!(p.branch_ub(a, 4, &mut dom, &mut trail));
        assert!(p.branch_lb(a, 4, &mut dom, &mut trail));
        assert_eq!(p.run(&m, &mut dom, &mut trail), PropResult::Consistent);
        p.check_invariants(&m, &dom);
        p.undo_to(&mut dom, &mut trail, mark);
        p.check_invariants(&m, &dom);
        assert_eq!((dom.lb(a), dom.ub(a)), (0, 10));
    }

    #[test]
    fn eq_self_requeue_reaches_closure() {
        // Mixed-sign equality whose ≥ pass strengthens its own ≤ pass:
        // 2x − 3y = 0 with x ∈ [0,9], y ∈ [1,5]. One ≤/≥ sweep only gets
        // x ≤ 7; the bounds fixpoint x ∈ [3,6], y ∈ [2,4] needs the visit
        // to re-enqueue itself until closure (the rule both engines share —
        // it makes the fixpoint independent of queue order).
        let mut m = CpModel::new();
        let x = m.int_var(0, 9, "x");
        let y = m.int_var(1, 5, "y");
        m.add_eq(LinExpr::new().add(2, x).add(-3, y), 0);
        let (dom, r) = prop(&m);
        assert_eq!(r, PropResult::Consistent);
        assert_eq!((dom.lb(x), dom.ub(x)), (3, 6));
        assert_eq!((dom.lb(y), dom.ub(y)), (2, 4));
    }
}
