//! Bounds propagation for linear constraints.
//!
//! Classic activity-based bound tightening: for `Σ aᵢxᵢ ≤ b`, the minimum
//! activity of all terms but one bounds the remaining term, which tightens
//! that variable's domain. Runs to fixpoint over a work queue; equalities
//! propagate in both directions. Used both at the root (presolve) and at
//! every node of the branch-and-bound search.

use super::model::{Cmp, CpModel, LinCon, Var};

/// Mutable view of variable domains during search. Bounds are trailed by the
/// search layer for backtracking.
#[derive(Debug, Clone)]
pub struct Domains {
    pub(crate) lb: Vec<i64>,
    pub(crate) ub: Vec<i64>,
}

impl Domains {
    /// Initial domains from the model's declared variable bounds.
    pub fn from_model(model: &CpModel) -> Self {
        Self {
            lb: model.vars.iter().map(|v| v.lb).collect(),
            ub: model.vars.iter().map(|v| v.ub).collect(),
        }
    }

    #[inline]
    pub fn lb(&self, v: Var) -> i64 {
        self.lb[v.index()]
    }

    #[inline]
    pub fn ub(&self, v: Var) -> i64 {
        self.ub[v.index()]
    }

    #[inline]
    pub fn is_fixed(&self, v: Var) -> bool {
        self.lb[v.index()] == self.ub[v.index()]
    }

    /// Every variable fixed?
    pub fn all_fixed(&self) -> bool {
        self.lb.iter().zip(&self.ub).all(|(l, u)| l == u)
    }

    /// Extract the (unique) assignment of fully-fixed domains.
    pub fn assignment(&self) -> Vec<i64> {
        debug_assert!(self.all_fixed());
        self.lb.clone()
    }
}

/// One bound change, recorded so the search can undo it on backtrack.
#[derive(Debug, Clone, Copy)]
pub enum TrailEntry {
    /// Variable's lower bound was raised from `old`.
    Lb(Var, i64),
    /// Variable's upper bound was lowered from `old`.
    Ub(Var, i64),
}

/// Result of a propagation round.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PropResult {
    /// Fixpoint reached, domains consistent.
    Consistent,
    /// Some domain emptied — the current node is infeasible.
    Infeasible,
}

/// Per-constraint cached activity bounds would be faster still, but the
/// compiler's partitioned subproblems stay small (see `compiler::partition`),
/// so a recompute-per-visit scheme with a var→constraints index is the
/// simplicity/speed sweet spot here.
pub struct Propagator {
    /// For each var, indices of constraints that mention it.
    watch: Vec<Vec<u32>>,
    /// Scratch queue of constraint indices to revisit.
    queue: Vec<u32>,
    /// Dedup flags for the queue.
    in_queue: Vec<bool>,
}

impl Propagator {
    /// Build the var→constraint watch lists for a model.
    pub fn new(model: &CpModel) -> Self {
        let mut watch = vec![Vec::new(); model.vars.len()];
        for (ci, c) in model.cons.iter().enumerate() {
            for &(_, v) in &c.terms {
                watch[v.index()].push(ci as u32);
            }
        }
        Self {
            watch,
            queue: Vec::new(),
            in_queue: vec![false; model.cons.len()],
        }
    }

    /// Propagate all constraints to fixpoint (root call).
    pub fn propagate_all(
        &mut self,
        model: &CpModel,
        dom: &mut Domains,
        trail: &mut Vec<TrailEntry>,
    ) -> PropResult {
        self.queue.clear();
        self.in_queue.iter_mut().for_each(|f| *f = false);
        for ci in 0..model.cons.len() {
            self.queue.push(ci as u32);
            self.in_queue[ci] = true;
        }
        self.run(model, dom, trail)
    }

    /// Propagate starting from the constraints watching `seed` (after the
    /// search fixed/tightened that variable).
    pub fn propagate_from(
        &mut self,
        model: &CpModel,
        dom: &mut Domains,
        trail: &mut Vec<TrailEntry>,
        seed: Var,
    ) -> PropResult {
        self.queue.clear();
        self.in_queue.iter_mut().for_each(|f| *f = false);
        for &ci in &self.watch[seed.index()] {
            if !self.in_queue[ci as usize] {
                self.queue.push(ci);
                self.in_queue[ci as usize] = true;
            }
        }
        self.run(model, dom, trail)
    }

    fn run(
        &mut self,
        model: &CpModel,
        dom: &mut Domains,
        trail: &mut Vec<TrailEntry>,
    ) -> PropResult {
        while let Some(ci) = self.queue.pop() {
            self.in_queue[ci as usize] = false;
            let con = &model.cons[ci as usize];
            let mut changed: Vec<Var> = Vec::new();
            if !tighten(con, dom, trail, &mut changed) {
                return PropResult::Infeasible;
            }
            for v in changed {
                for &cj in &self.watch[v.index()] {
                    if cj != ci && !self.in_queue[cj as usize] {
                        self.queue.push(cj);
                        self.in_queue[cj as usize] = true;
                    }
                }
            }
        }
        PropResult::Consistent
    }
}

/// Tighten domains w.r.t. one constraint. Returns false on infeasibility;
/// records changed variables in `changed` and bound changes on `trail`.
fn tighten(
    con: &LinCon,
    dom: &mut Domains,
    trail: &mut Vec<TrailEntry>,
    changed: &mut Vec<Var>,
) -> bool {
    // Treat Eq as both Le and Ge.
    let (do_le, do_ge) = match con.cmp {
        Cmp::Le => (true, false),
        Cmp::Ge => (false, true),
        Cmp::Eq => (true, true),
    };
    if do_le && !tighten_le(&con.terms, con.rhs, dom, trail, changed) {
        return false;
    }
    if do_ge {
        // Σ aᵢxᵢ ≥ b  ⇔  Σ (-aᵢ)xᵢ ≤ -b
        if !tighten_le_neg(&con.terms, -con.rhs, dom, trail, changed) {
            return false;
        }
    }
    true
}

#[inline]
fn term_min(c: i64, lb: i64, ub: i64) -> i64 {
    if c >= 0 {
        c * lb
    } else {
        c * ub
    }
}

#[inline]
fn term_max(c: i64, lb: i64, ub: i64) -> i64 {
    if c >= 0 {
        c * ub
    } else {
        c * lb
    }
}

fn set_ub(v: Var, new_ub: i64, dom: &mut Domains, trail: &mut Vec<TrailEntry>, changed: &mut Vec<Var>) -> bool {
    let i = v.index();
    if new_ub < dom.ub[i] {
        trail.push(TrailEntry::Ub(v, dom.ub[i]));
        dom.ub[i] = new_ub;
        changed.push(v);
        if dom.lb[i] > new_ub {
            return false;
        }
    }
    true
}

fn set_lb(v: Var, new_lb: i64, dom: &mut Domains, trail: &mut Vec<TrailEntry>, changed: &mut Vec<Var>) -> bool {
    let i = v.index();
    if new_lb > dom.lb[i] {
        trail.push(TrailEntry::Lb(v, dom.lb[i]));
        dom.lb[i] = new_lb;
        changed.push(v);
        if dom.ub[i] < new_lb {
            return false;
        }
    }
    true
}

/// Tighten for `Σ aᵢxᵢ ≤ b` with coefficients as stored.
fn tighten_le(
    terms: &[(i64, Var)],
    rhs: i64,
    dom: &mut Domains,
    trail: &mut Vec<TrailEntry>,
    changed: &mut Vec<Var>,
) -> bool {
    let min_act: i64 = terms
        .iter()
        .map(|&(c, v)| term_min(c, dom.lb(v), dom.ub(v)))
        .sum();
    if min_act > rhs {
        return false;
    }
    for &(c, v) in terms {
        let rest = min_act - term_min(c, dom.lb(v), dom.ub(v));
        // c*x ≤ rhs - rest
        let cap = rhs - rest;
        if c > 0 {
            let new_ub = cap.div_euclid(c);
            if !set_ub(v, new_ub, dom, trail, changed) {
                return false;
            }
        } else if c < 0 {
            // x ≥ ceil(cap / c) with c negative
            let new_lb = -((-cap).div_euclid(-c)); // careful integer division
            let new_lb = if c * new_lb > cap { new_lb + 1 } else { new_lb };
            // Simpler: smallest x with c*x ≤ cap is ceil(cap/c) for c<0.
            let exact = div_ceil(cap, c);
            debug_assert!(c * exact <= cap);
            let _ = new_lb;
            if !set_lb(v, exact, dom, trail, changed) {
                return false;
            }
        }
    }
    true
}

/// Tighten for `Σ (-aᵢ)xᵢ ≤ b` (negated view for ≥ constraints).
fn tighten_le_neg(
    terms: &[(i64, Var)],
    rhs: i64,
    dom: &mut Domains,
    trail: &mut Vec<TrailEntry>,
    changed: &mut Vec<Var>,
) -> bool {
    let min_act: i64 = terms
        .iter()
        .map(|&(c, v)| term_min(-c, dom.lb(v), dom.ub(v)))
        .sum();
    if min_act > rhs {
        return false;
    }
    for &(c, v) in terms {
        let nc = -c;
        let rest = min_act - term_min(nc, dom.lb(v), dom.ub(v));
        let cap = rhs - rest;
        if nc > 0 {
            if !set_ub(v, cap.div_euclid(nc), dom, trail, changed) {
                return false;
            }
        } else if nc < 0 {
            if !set_lb(v, div_ceil(cap, nc), dom, trail, changed) {
                return false;
            }
        }
    }
    true
}

/// Ceiling division for possibly-negative divisor: smallest x with d*x ≤ cap
/// when d < 0 is x = ceil(cap/d).
#[inline]
fn div_ceil(cap: i64, d: i64) -> i64 {
    debug_assert!(d != 0);
    let q = cap / d;
    if cap % d != 0 && ((cap < 0) == (d < 0)) {
        q + 1
    } else {
        q
    }
}

/// Minimum possible value of a linear expression under current domains —
/// the objective lower bound used for pruning.
pub fn expr_min(terms: &[(i64, Var)], constant: i64, dom: &Domains) -> i64 {
    constant
        + terms
            .iter()
            .map(|&(c, v)| term_min(c, dom.lb(v), dom.ub(v)))
            .sum::<i64>()
}

/// Maximum possible value of a linear expression under current domains.
pub fn expr_max(terms: &[(i64, Var)], constant: i64, dom: &Domains) -> i64 {
    constant
        + terms
            .iter()
            .map(|&(c, v)| term_max(c, dom.lb(v), dom.ub(v)))
            .sum::<i64>()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cp::model::LinExpr;

    fn prop(model: &CpModel) -> (Domains, PropResult) {
        let mut dom = Domains::from_model(model);
        let mut p = Propagator::new(model);
        let mut trail = Vec::new();
        let r = p.propagate_all(model, &mut dom, &mut trail);
        (dom, r)
    }

    #[test]
    fn le_tightens_upper_bounds() {
        let mut m = CpModel::new();
        let a = m.int_var(0, 10, "a");
        let b = m.int_var(0, 10, "b");
        m.add_le(LinExpr::new().add(1, a).add(1, b), 4);
        let (dom, r) = prop(&m);
        assert_eq!(r, PropResult::Consistent);
        assert_eq!(dom.ub(a), 4);
        assert_eq!(dom.ub(b), 4);
    }

    #[test]
    fn eq_fixes_when_forced() {
        let mut m = CpModel::new();
        let a = m.int_var(0, 10, "a");
        let b = m.int_var(3, 3, "b");
        m.add_eq(LinExpr::new().add(1, a).add(1, b), 5);
        let (dom, r) = prop(&m);
        assert_eq!(r, PropResult::Consistent);
        assert_eq!((dom.lb(a), dom.ub(a)), (2, 2));
    }

    #[test]
    fn detects_infeasible() {
        let mut m = CpModel::new();
        let a = m.int_var(0, 1, "a");
        let b = m.int_var(0, 1, "b");
        m.add_ge(LinExpr::new().add(1, a).add(1, b), 3);
        let (_, r) = prop(&m);
        assert_eq!(r, PropResult::Infeasible);
    }

    #[test]
    fn negative_coefficients() {
        let mut m = CpModel::new();
        let a = m.int_var(0, 10, "a");
        let b = m.int_var(0, 10, "b");
        // a - b ≤ -5  ⇒  a ≤ b - 5 ⇒ a ≤ 5, b ≥ 5
        m.add_le(LinExpr::new().add(1, a).add(-1, b), -5);
        let (dom, r) = prop(&m);
        assert_eq!(r, PropResult::Consistent);
        assert_eq!(dom.ub(a), 5);
        assert_eq!(dom.lb(b), 5);
    }

    #[test]
    fn implication_chain_propagates() {
        let mut m = CpModel::new();
        let a = m.bool_var("a");
        let b = m.bool_var("b");
        let c = m.bool_var("c");
        m.add_implication(a, b);
        m.add_implication(b, c);
        m.add_ge(LinExpr::var(a), 1); // a = 1
        let (dom, r) = prop(&m);
        assert_eq!(r, PropResult::Consistent);
        assert_eq!(dom.lb(b), 1);
        assert_eq!(dom.lb(c), 1);
    }

    #[test]
    fn expr_min_max() {
        let mut m = CpModel::new();
        let a = m.int_var(1, 3, "a");
        let b = m.int_var(-2, 2, "b");
        let dom = Domains::from_model(&m);
        let terms = [(2i64, a), (-1i64, b)];
        assert_eq!(expr_min(&terms, 0, &dom), 2 * 1 - 2);
        assert_eq!(expr_max(&terms, 0, &dom), 2 * 3 + 2);
    }

    #[test]
    fn div_ceil_signs() {
        assert_eq!(div_ceil(7, -2), -3); // smallest x with -2x ≤ 7 → x ≥ -3.5 → -3
        assert_eq!(div_ceil(-7, -2), 4); // -2x ≤ -7 → x ≥ 3.5 → 4
        assert_eq!(div_ceil(6, -3), -2);
        assert_eq!(div_ceil(-6, -3), 2);
    }
}
