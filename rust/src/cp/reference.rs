//! The frozen recompute-per-visit propagation engine — the differential
//! oracle for [`super::propagate`].
//!
//! This is the original engine the compiler shipped with before the
//! incremental rewrite: every constraint visit recomputes its activity
//! bounds from scratch (O(terms) per visit), the work queue is a plain LIFO
//! stack, and there is no entailment detection. It is kept — unoptimized, on
//! purpose — so `rust/tests/cp_differential.rs` and
//! `benches/solver_hotpath.rs` can prove/measure the incremental engine
//! against it, selected via
//! [`EngineKind::Reference`](super::search::EngineKind).
//!
//! One deliberate change is shared with the incremental engine: an equality
//! constraint whose own visit moved a bound re-enqueues itself (its `≤` and
//! `≥` passes can feed each other, so a single visit may not reach the
//! constraint's closure). With that rule, every propagation run converges to
//! the unique greatest common fixpoint of the per-constraint tighteners
//! *regardless of queue order* — which is exactly what makes the two
//! engines' search trees provably identical node for node (see
//! `docs/solver.md`).

use std::time::Instant;

use super::model::{Cmp, CpModel, LinCon, Var};
use super::propagate::{
    div_ceil, expr_min, term_min, Domains, PropResult, TrailEntry,
};
use super::search::{
    objective_terms, validate_hint, SearchConfig, Solution, SolveStats, Status,
};

/// The original recompute-per-visit propagator: var→constraint watch lists,
/// LIFO queue, activity recomputed at every visit.
struct RefPropagator {
    /// For each var, indices of constraints that mention it.
    watch: Vec<Vec<u32>>,
    /// Scratch queue of constraint indices to revisit.
    queue: Vec<u32>,
    /// Dedup flags for the queue.
    in_queue: Vec<bool>,
    /// Constraint visits (for [`SolveStats::propagations`]).
    propagations: u64,
    /// Successful bound changes (for [`SolveStats::tightenings`]).
    tightenings: u64,
}

impl RefPropagator {
    fn new(model: &CpModel) -> Self {
        let mut watch = vec![Vec::new(); model.vars.len()];
        for (ci, c) in model.cons.iter().enumerate() {
            for &(_, v) in &c.terms {
                watch[v.index()].push(ci as u32);
            }
        }
        Self {
            watch,
            queue: Vec::new(),
            in_queue: vec![false; model.cons.len()],
            propagations: 0,
            tightenings: 0,
        }
    }

    /// Propagate all constraints to fixpoint (root call).
    fn propagate_all(
        &mut self,
        model: &CpModel,
        dom: &mut Domains,
        trail: &mut Vec<TrailEntry>,
    ) -> PropResult {
        self.queue.clear();
        self.in_queue.iter_mut().for_each(|f| *f = false);
        for ci in 0..model.cons.len() {
            self.queue.push(ci as u32);
            self.in_queue[ci] = true;
        }
        self.run(model, dom, trail)
    }

    /// Propagate starting from the constraints watching `seed` (after the
    /// search fixed/tightened that variable).
    fn propagate_from(
        &mut self,
        model: &CpModel,
        dom: &mut Domains,
        trail: &mut Vec<TrailEntry>,
        seed: Var,
    ) -> PropResult {
        self.queue.clear();
        self.in_queue.iter_mut().for_each(|f| *f = false);
        for &ci in &self.watch[seed.index()] {
            if !self.in_queue[ci as usize] {
                self.queue.push(ci);
                self.in_queue[ci as usize] = true;
            }
        }
        self.run(model, dom, trail)
    }

    fn run(
        &mut self,
        model: &CpModel,
        dom: &mut Domains,
        trail: &mut Vec<TrailEntry>,
    ) -> PropResult {
        while let Some(ci) = self.queue.pop() {
            self.in_queue[ci as usize] = false;
            let con = &model.cons[ci as usize];
            self.propagations += 1;
            let mut changed: Vec<Var> = Vec::new();
            if !tighten(con, dom, trail, &mut changed, &mut self.tightenings) {
                return PropResult::Infeasible;
            }
            let self_closure = con.cmp == Cmp::Eq && !changed.is_empty();
            for v in changed {
                for &cj in &self.watch[v.index()] {
                    if cj != ci && !self.in_queue[cj as usize] {
                        self.queue.push(cj);
                        self.in_queue[cj as usize] = true;
                    }
                }
            }
            // Shared closure rule (see module doc): a changed equality
            // revisits itself until its two passes stop feeding each other.
            if self_closure && !self.in_queue[ci as usize] {
                self.queue.push(ci);
                self.in_queue[ci as usize] = true;
            }
        }
        PropResult::Consistent
    }
}

/// Tighten domains w.r.t. one constraint. Returns false on infeasibility;
/// records changed variables in `changed` and bound changes on `trail`.
fn tighten(
    con: &LinCon,
    dom: &mut Domains,
    trail: &mut Vec<TrailEntry>,
    changed: &mut Vec<Var>,
    tightenings: &mut u64,
) -> bool {
    // Treat Eq as both Le and Ge.
    let (do_le, do_ge) = match con.cmp {
        Cmp::Le => (true, false),
        Cmp::Ge => (false, true),
        Cmp::Eq => (true, true),
    };
    if do_le && !tighten_le(&con.terms, con.rhs, dom, trail, changed, tightenings) {
        return false;
    }
    if do_ge {
        // Σ aᵢxᵢ ≥ b  ⇔  Σ (-aᵢ)xᵢ ≤ -b
        if !tighten_le_neg(&con.terms, -con.rhs, dom, trail, changed, tightenings) {
            return false;
        }
    }
    true
}

fn set_ub(
    v: Var,
    new_ub: i64,
    dom: &mut Domains,
    trail: &mut Vec<TrailEntry>,
    changed: &mut Vec<Var>,
    tightenings: &mut u64,
) -> bool {
    let i = v.index();
    if new_ub < dom.ub[i] {
        trail.push(TrailEntry::Ub(v, dom.ub[i]));
        dom.ub[i] = new_ub;
        changed.push(v);
        *tightenings += 1;
        if dom.lb[i] > new_ub {
            return false;
        }
    }
    true
}

fn set_lb(
    v: Var,
    new_lb: i64,
    dom: &mut Domains,
    trail: &mut Vec<TrailEntry>,
    changed: &mut Vec<Var>,
    tightenings: &mut u64,
) -> bool {
    let i = v.index();
    if new_lb > dom.lb[i] {
        trail.push(TrailEntry::Lb(v, dom.lb[i]));
        dom.lb[i] = new_lb;
        changed.push(v);
        *tightenings += 1;
        if dom.ub[i] < new_lb {
            return false;
        }
    }
    true
}

/// Tighten for `Σ aᵢxᵢ ≤ b` with coefficients as stored, recomputing the
/// minimum activity from the domains (the cost the incremental engine's
/// caches eliminate).
fn tighten_le(
    terms: &[(i64, Var)],
    rhs: i64,
    dom: &mut Domains,
    trail: &mut Vec<TrailEntry>,
    changed: &mut Vec<Var>,
    tightenings: &mut u64,
) -> bool {
    let min_act: i64 = terms
        .iter()
        .map(|&(c, v)| term_min(c, dom.lb(v), dom.ub(v)))
        .sum();
    if min_act > rhs {
        return false;
    }
    for &(c, v) in terms {
        let rest = min_act - term_min(c, dom.lb(v), dom.ub(v));
        // c*x ≤ rhs - rest
        let cap = rhs - rest;
        if c > 0 {
            if !set_ub(v, cap.div_euclid(c), dom, trail, changed, tightenings) {
                return false;
            }
        } else if c < 0 {
            // Smallest x with c*x ≤ cap is ceil(cap/c) for c<0.
            if !set_lb(v, div_ceil(cap, c), dom, trail, changed, tightenings) {
                return false;
            }
        }
    }
    true
}

/// Tighten for `Σ (-aᵢ)xᵢ ≤ b` (negated view for ≥ constraints).
fn tighten_le_neg(
    terms: &[(i64, Var)],
    rhs: i64,
    dom: &mut Domains,
    trail: &mut Vec<TrailEntry>,
    changed: &mut Vec<Var>,
    tightenings: &mut u64,
) -> bool {
    let min_act: i64 = terms
        .iter()
        .map(|&(c, v)| term_min(-c, dom.lb(v), dom.ub(v)))
        .sum();
    if min_act > rhs {
        return false;
    }
    for &(c, v) in terms {
        let nc = -c;
        let rest = min_act - term_min(nc, dom.lb(v), dom.ub(v));
        let cap = rhs - rest;
        if nc > 0 {
            if !set_ub(v, cap.div_euclid(nc), dom, trail, changed, tightenings) {
                return false;
            }
        } else if nc < 0 {
            if !set_lb(v, div_ceil(cap, nc), dom, trail, changed, tightenings) {
                return false;
            }
        }
    }
    true
}

struct RefSearchCtx<'m> {
    model: &'m CpModel,
    prop: RefPropagator,
    dom: Domains,
    trail: Vec<TrailEntry>,
    obj_terms: Vec<(i64, Var)>,
    obj_const: i64,
    best: Option<(i64, Vec<i64>)>,
    nodes: u64,
    start: Instant,
    cfg: SearchConfig,
    limit_hit: bool,
    backtracks: u64,
    peak_trail: u64,
    last_conflict: Option<Var>,
}

impl<'m> RefSearchCtx<'m> {
    /// Plain trail unwind (no caches to restore), with the same stat
    /// accounting as the incremental engine's `backtrack_to`.
    fn backtrack_to(&mut self, mark: usize) {
        self.peak_trail = self.peak_trail.max(self.trail.len() as u64);
        self.backtracks += 1;
        while self.trail.len() > mark {
            match self.trail.pop().unwrap() {
                TrailEntry::Lb(v, old) => self.dom.lb[v.index()] = old,
                TrailEntry::Ub(v, old) => self.dom.ub[v.index()] = old,
                // The reference engine never trails entailment events.
                TrailEntry::Entailed(_) => unreachable!("reference engine has no entailment"),
            }
        }
    }

    fn limits_exceeded(&mut self) -> bool {
        if self.limit_hit {
            return true;
        }
        if let Some(n) = self.cfg.node_limit {
            if self.nodes >= n {
                self.limit_hit = true;
                return true;
            }
        }
        if let Some(ms) = self.cfg.time_limit_ms {
            // Check time only periodically — Instant::now is not free.
            if self.nodes % 256 == 0 && self.start.elapsed().as_millis() as u64 >= ms {
                self.limit_hit = true;
                return true;
            }
        }
        false
    }

    /// Identical selection rule to the incremental engine: last-conflict
    /// refinement (when enabled), else smallest domain with index tie-break.
    fn select_var(&self) -> Option<Var> {
        if self.cfg.last_conflict {
            if let Some(v) = self.last_conflict {
                if self.dom.ub(v) > self.dom.lb(v) {
                    return Some(v);
                }
            }
        }
        let mut best: Option<(i64, usize)> = None;
        for i in 0..self.dom.lb.len() {
            let w = self.dom.ub[i] - self.dom.lb[i];
            if w > 0 {
                match best {
                    Some((bw, _)) if bw <= w => {}
                    _ => best = Some((w, i)),
                }
            }
        }
        best.map(|(_, i)| Var(i as u32))
    }

    fn obj_coef(&self, v: Var) -> i64 {
        self.obj_terms
            .binary_search_by_key(&v, |&(_, var)| var)
            .map(|i| self.obj_terms[i].0)
            .unwrap_or(0)
    }

    fn dfs(&mut self) {
        self.nodes += 1;
        if self.limits_exceeded() {
            return;
        }

        if let Some((best_obj, _)) = &self.best {
            let lb = expr_min(&self.obj_terms, self.obj_const, &self.dom);
            if lb >= *best_obj {
                return;
            }
        }

        let Some(v) = self.select_var() else {
            let assignment = self.dom.assignment();
            let obj = expr_min(&self.obj_terms, self.obj_const, &self.dom);
            debug_assert!(self.model.violated(&assignment).is_none());
            let better = match &self.best {
                Some((b, _)) => obj < *b,
                None => true,
            };
            if better {
                self.best = Some((obj, assignment));
            }
            return;
        };

        let coef = self.obj_coef(v);
        let lb_first = coef >= 0;
        let (first_is_lb, second_is_lb) = (lb_first, !lb_first);
        for is_lb in [first_is_lb, second_is_lb] {
            if self.limit_hit {
                return;
            }
            let mark = self.trail.len();
            if is_lb {
                let val = self.dom.lb(v);
                let old = self.dom.ub[v.index()];
                if old != val {
                    self.trail.push(TrailEntry::Ub(v, old));
                    self.dom.ub[v.index()] = val;
                }
            } else {
                let val = self.dom.ub(v);
                let old = self.dom.lb[v.index()];
                if old != val {
                    self.trail.push(TrailEntry::Lb(v, old));
                    self.dom.lb[v.index()] = val;
                }
            }
            let res = self
                .prop
                .propagate_from(self.model, &mut self.dom, &mut self.trail, v);
            if res == PropResult::Consistent {
                self.dfs();
                if self.cfg.first_solution_only && self.best.is_some() {
                    self.backtrack_to(mark);
                    return;
                }
            } else {
                self.last_conflict = Some(v);
            }
            self.backtrack_to(mark);

            if is_lb == first_is_lb {
                let mark2 = self.trail.len();
                let feas = if first_is_lb {
                    let nv = self.dom.lb(v) + 1;
                    if nv > self.dom.ub(v) {
                        false
                    } else {
                        self.trail.push(TrailEntry::Lb(v, nv - 1));
                        self.dom.lb[v.index()] = nv;
                        true
                    }
                } else {
                    let nv = self.dom.ub(v) - 1;
                    if nv < self.dom.lb(v) {
                        false
                    } else {
                        self.trail.push(TrailEntry::Ub(v, nv + 1));
                        self.dom.ub[v.index()] = nv;
                        true
                    }
                };
                if !feas {
                    return; // domain exhausted; both branches done
                }
                let res = self
                    .prop
                    .propagate_from(self.model, &mut self.dom, &mut self.trail, v);
                if res == PropResult::Infeasible {
                    self.last_conflict = Some(v);
                    self.backtrack_to(mark2);
                    return;
                }
                self.dfs();
                self.backtrack_to(mark2);
                return;
            }
        }
    }
}

/// Solve `model` with the frozen reference engine. Same search tree, same
/// result surface as [`super::search::solve`] with the default engine —
/// only `solve_ms` and the propagation-layer counters differ.
pub fn solve_reference(model: &CpModel, cfg: SearchConfig) -> Solution {
    let start = Instant::now();
    let mut dom = Domains::from_model(model);
    let mut prop = RefPropagator::new(model);
    let mut trail = Vec::new();

    let (obj_terms, obj_const) = objective_terms(model);
    let (initial_best, hints_rejected) = validate_hint(model, &cfg, &obj_terms, obj_const);

    if prop.propagate_all(model, &mut dom, &mut trail) == PropResult::Infeasible {
        return Solution {
            status: Status::Infeasible,
            assignment: None,
            objective: None,
            nodes: 0,
            solve_ms: start.elapsed().as_millis() as u64,
            stats: SolveStats {
                nodes: 0,
                propagations: prop.propagations,
                tightenings: prop.tightenings,
                entailments: 0,
                backtracks: 0,
                peak_trail: trail.len() as u64,
                hints_rejected,
            },
        };
    }

    let mut ctx = RefSearchCtx {
        model,
        prop,
        dom,
        trail,
        obj_terms,
        obj_const,
        best: initial_best,
        nodes: 0,
        start,
        cfg,
        limit_hit: false,
        backtracks: 0,
        peak_trail: 0,
        last_conflict: None,
    };
    ctx.dfs();

    let solve_ms = ctx.start.elapsed().as_millis() as u64;
    let stats = SolveStats {
        nodes: ctx.nodes,
        propagations: ctx.prop.propagations,
        tightenings: ctx.prop.tightenings,
        entailments: 0,
        backtracks: ctx.backtracks,
        peak_trail: ctx.peak_trail.max(ctx.trail.len() as u64),
        hints_rejected,
    };
    match ctx.best {
        Some((obj, assignment)) => Solution {
            status: if ctx.limit_hit { Status::Feasible } else { Status::Optimal },
            objective: Some(obj),
            assignment: Some(assignment),
            nodes: ctx.nodes,
            solve_ms,
            stats,
        },
        None => Solution {
            status: if ctx.limit_hit { Status::Unknown } else { Status::Infeasible },
            objective: None,
            assignment: None,
            nodes: ctx.nodes,
            solve_ms,
            stats,
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cp::model::LinExpr;
    use crate::cp::search::EngineKind;

    #[test]
    fn reference_engine_solves_and_reports_no_entailments() {
        let mut m = CpModel::new();
        let x = m.int_var(0, 5, "x");
        let y = m.int_var(0, 5, "y");
        m.add_ge(LinExpr::sum([x, y]), 3);
        m.minimize(LinExpr::sum([x, y]));
        let s = solve_reference(
            &m,
            SearchConfig { engine: EngineKind::Reference, ..Default::default() },
        );
        assert_eq!(s.status, Status::Optimal);
        assert_eq!(s.objective, Some(3));
        assert_eq!(s.stats.entailments, 0);
        assert!(s.stats.propagations > 0);
    }

    #[test]
    fn reference_reaches_eq_closure_like_the_incremental_engine() {
        // Same model as propagate.rs::eq_self_requeue_reaches_closure: the
        // shared self-requeue rule must give the reference the same (tight)
        // root fixpoint, hence identical trees downstream.
        let mut m = CpModel::new();
        let x = m.int_var(0, 9, "x");
        let y = m.int_var(1, 5, "y");
        m.add_eq(LinExpr::new().add(2, x).add(-3, y), 0);
        let mut dom = Domains::from_model(&m);
        let mut p = RefPropagator::new(&m);
        let mut trail = Vec::new();
        assert_eq!(p.propagate_all(&m, &mut dom, &mut trail), PropResult::Consistent);
        assert_eq!((dom.lb(x), dom.ub(x)), (3, 6));
        assert_eq!((dom.lb(y), dom.ub(y)), (2, 4));
    }
}
