//! Constraint-programming model: integer/boolean variables, linear
//! expressions, and linear constraints.
//!
//! This is the substrate the paper's compiler mid-end builds its three CP
//! problems on (tiling+fusion, scheduling, allocation — Sec. IV-B/C/D).
//! The model is a bounded-integer linear CP: every variable has finite
//! bounds, every constraint is `Σ aᵢ·xᵢ ⋈ b` with `⋈ ∈ {≤, =, ≥}`, and the
//! objective (if any) is a linear expression to minimize.

use std::fmt;

/// Handle to a decision variable inside a [`CpModel`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct Var(pub(crate) u32);

impl Var {
    /// Index of this variable in the owning model.
    #[inline]
    pub fn index(self) -> usize {
        self.0 as usize
    }
}

/// Comparison operator of a linear constraint.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Cmp {
    /// `expr ≤ rhs`
    Le,
    /// `expr = rhs`
    Eq,
    /// `expr ≥ rhs`
    Ge,
}

/// A linear expression `Σ coef·var + constant` over model variables.
#[derive(Debug, Clone, Default)]
pub struct LinExpr {
    pub(crate) terms: Vec<(i64, Var)>,
    pub(crate) constant: i64,
}

impl LinExpr {
    /// The zero expression.
    pub fn new() -> Self {
        Self::default()
    }

    /// Expression consisting of a single variable with coefficient 1.
    pub fn var(v: Var) -> Self {
        Self { terms: vec![(1, v)], constant: 0 }
    }

    /// Expression consisting of a constant only.
    pub fn constant(c: i64) -> Self {
        Self { terms: Vec::new(), constant: c }
    }

    /// Add `coef * v` to the expression (builder style).
    pub fn add(mut self, coef: i64, v: Var) -> Self {
        self.push(coef, v);
        self
    }

    /// Add a constant offset (builder style).
    pub fn add_const(mut self, c: i64) -> Self {
        self.constant += c;
        self
    }

    /// Push `coef * v` in place.
    pub fn push(&mut self, coef: i64, v: Var) {
        if coef != 0 {
            self.terms.push((coef, v));
        }
    }

    /// Sum of unit-coefficient variables.
    pub fn sum(vars: impl IntoIterator<Item = Var>) -> Self {
        let mut e = Self::new();
        for v in vars {
            e.push(1, v);
        }
        e
    }

    /// Weighted sum.
    pub fn weighted_sum(terms: impl IntoIterator<Item = (i64, Var)>) -> Self {
        let mut e = Self::new();
        for (c, v) in terms {
            e.push(c, v);
        }
        e
    }

    /// Merge duplicate variables, dropping zero coefficients. Keeps the
    /// expression canonical so propagation bounds are as tight as possible.
    pub fn normalize(&mut self) {
        self.terms.sort_by_key(|&(_, v)| v);
        let mut out: Vec<(i64, Var)> = Vec::with_capacity(self.terms.len());
        for &(c, v) in &self.terms {
            match out.last_mut() {
                Some(last) if last.1 == v => last.0 += c,
                _ => out.push((c, v)),
            }
        }
        out.retain(|&(c, _)| c != 0);
        self.terms = out;
    }

    /// Evaluate under a full assignment (slice indexed by var index).
    pub fn eval(&self, assignment: &[i64]) -> i64 {
        self.constant
            + self
                .terms
                .iter()
                .map(|&(c, v)| c * assignment[v.index()])
                .sum::<i64>()
    }

    /// Number of terms.
    pub fn len(&self) -> usize {
        self.terms.len()
    }

    /// True if the expression has no variable terms.
    pub fn is_empty(&self) -> bool {
        self.terms.is_empty()
    }
}

/// A linear constraint `expr ⋈ rhs` (rhs folded into expr's constant at
/// construction: stored as `Σ aᵢxᵢ ⋈ b`).
#[derive(Debug, Clone)]
pub struct LinCon {
    pub(crate) terms: Vec<(i64, Var)>,
    pub(crate) cmp: Cmp,
    pub(crate) rhs: i64,
    /// Optional label for debugging / infeasibility reporting.
    pub(crate) name: Option<String>,
}

impl LinCon {
    /// Check the constraint under a full assignment.
    pub fn check(&self, assignment: &[i64]) -> bool {
        let lhs: i64 = self
            .terms
            .iter()
            .map(|&(c, v)| c * assignment[v.index()])
            .sum();
        match self.cmp {
            Cmp::Le => lhs <= self.rhs,
            Cmp::Eq => lhs == self.rhs,
            Cmp::Ge => lhs >= self.rhs,
        }
    }
}

#[derive(Debug, Clone)]
pub(crate) struct VarInfo {
    pub lb: i64,
    pub ub: i64,
    pub name: Option<String>,
}

/// A constraint-programming model: variables + linear constraints + an
/// optional linear minimization objective.
#[derive(Debug, Default, Clone)]
pub struct CpModel {
    pub(crate) vars: Vec<VarInfo>,
    pub(crate) cons: Vec<LinCon>,
    pub(crate) objective: Option<LinExpr>,
}

impl CpModel {
    /// Empty model.
    pub fn new() -> Self {
        Self::default()
    }

    /// New integer variable with inclusive bounds `[lb, ub]`.
    pub fn int_var(&mut self, lb: i64, ub: i64, name: impl Into<String>) -> Var {
        assert!(lb <= ub, "int_var: empty domain [{lb}, {ub}]");
        let v = Var(self.vars.len() as u32);
        self.vars.push(VarInfo { lb, ub, name: Some(name.into()) });
        v
    }

    /// New boolean (0/1) variable.
    pub fn bool_var(&mut self, name: impl Into<String>) -> Var {
        self.int_var(0, 1, name)
    }

    /// New variable fixed to a constant.
    pub fn const_var(&mut self, value: i64) -> Var {
        self.int_var(value, value, format!("const_{value}"))
    }

    /// Number of variables.
    pub fn num_vars(&self) -> usize {
        self.vars.len()
    }

    /// Number of constraints.
    pub fn num_constraints(&self) -> usize {
        self.cons.len()
    }

    /// Current bounds of a variable.
    pub fn bounds(&self, v: Var) -> (i64, i64) {
        let info = &self.vars[v.index()];
        (info.lb, info.ub)
    }

    /// Add `expr ⋈ rhs`. The expression's constant is folded into the rhs.
    pub fn add(&mut self, mut expr: LinExpr, cmp: Cmp, rhs: i64) {
        self.add_named(std::mem::take(&mut expr), cmp, rhs, None)
    }

    /// Add a named constraint (name used in infeasibility diagnostics).
    pub fn add_named(&mut self, mut expr: LinExpr, cmp: Cmp, rhs: i64, name: Option<String>) {
        expr.normalize();
        let rhs = rhs - expr.constant;
        if expr.terms.is_empty() {
            // Constant constraint: record as trivially-checkable sentinel so
            // infeasible models are caught at solve time, not silently.
            let ok = match cmp {
                Cmp::Le => 0 <= rhs,
                Cmp::Eq => 0 == rhs,
                Cmp::Ge => 0 >= rhs,
            };
            if ok {
                return;
            }
        }
        self.cons.push(LinCon { terms: expr.terms, cmp, rhs, name });
    }

    /// `expr ≤ rhs`
    pub fn add_le(&mut self, expr: LinExpr, rhs: i64) {
        self.add(expr, Cmp::Le, rhs);
    }

    /// `expr = rhs`
    pub fn add_eq(&mut self, expr: LinExpr, rhs: i64) {
        self.add(expr, Cmp::Eq, rhs);
    }

    /// `expr ≥ rhs`
    pub fn add_ge(&mut self, expr: LinExpr, rhs: i64) {
        self.add(expr, Cmp::Ge, rhs);
    }

    /// Boolean implication `a ⇒ b` encoded as `a ≤ b`.
    pub fn add_implication(&mut self, a: Var, b: Var) {
        self.add_le(LinExpr::var(a).add(-1, b), 0);
    }

    /// At most one of `vars` is 1.
    pub fn add_at_most_one(&mut self, vars: impl IntoIterator<Item = Var>) {
        self.add_le(LinExpr::sum(vars), 1);
    }

    /// Exactly one of `vars` is 1.
    pub fn add_exactly_one(&mut self, vars: impl IntoIterator<Item = Var>) {
        self.add_eq(LinExpr::sum(vars), 1);
    }

    /// `target ≥ expr` for each expr — used for max-style variables
    /// (e.g. highest TCM bank used by a tensor, Eq. (5) in the paper).
    pub fn add_max_ge(&mut self, target: Var, exprs: impl IntoIterator<Item = LinExpr>) {
        for e in exprs {
            // target - e >= 0
            let mut ex = LinExpr::var(target);
            ex.constant -= e.constant;
            for (c, v) in e.terms {
                ex.push(-c, v);
            }
            self.add_ge(ex, 0);
        }
    }

    /// `target ≤ expr` for each expr — min-style variables (Eq. (4)).
    pub fn add_min_le(&mut self, target: Var, exprs: impl IntoIterator<Item = LinExpr>) {
        for e in exprs {
            let mut ex = LinExpr::var(target);
            ex.constant -= e.constant;
            for (c, v) in e.terms {
                ex.push(-c, v);
            }
            self.add_le(ex, 0);
        }
    }

    /// Set (replace) the minimization objective.
    pub fn minimize(&mut self, mut obj: LinExpr) {
        obj.normalize();
        self.objective = Some(obj);
    }

    /// Verify a full assignment against every constraint; returns the first
    /// violated constraint's description, if any.
    pub fn violated(&self, assignment: &[i64]) -> Option<String> {
        for (i, (info, &val)) in self.vars.iter().zip(assignment).enumerate() {
            if val < info.lb || val > info.ub {
                return Some(format!(
                    "var {} ({:?}) = {} outside [{}, {}]",
                    i, info.name, val, info.lb, info.ub
                ));
            }
        }
        for (i, c) in self.cons.iter().enumerate() {
            if !c.check(assignment) {
                return Some(format!("constraint {} ({:?}) violated", i, c.name));
            }
        }
        None
    }
}

impl fmt::Display for CpModel {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "CpModel({} vars, {} constraints, objective: {})",
            self.vars.len(),
            self.cons.len(),
            if self.objective.is_some() { "min" } else { "none" }
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn linexpr_normalize_merges_and_drops_zeros() {
        let mut m = CpModel::new();
        let a = m.bool_var("a");
        let b = m.bool_var("b");
        let mut e = LinExpr::new().add(2, a).add(3, b).add(-2, a).add(1, b);
        e.normalize();
        assert_eq!(e.terms, vec![(4, b)]);
    }

    #[test]
    fn linexpr_eval() {
        let mut m = CpModel::new();
        let a = m.int_var(0, 10, "a");
        let b = m.int_var(0, 10, "b");
        let e = LinExpr::new().add(2, a).add(-1, b).add_const(5);
        assert_eq!(e.eval(&[3, 4]), 2 * 3 - 4 + 5);
        let _ = (a, b);
    }

    #[test]
    fn constant_constraint_checked() {
        let mut m = CpModel::new();
        // 0 <= -1 is infeasible and must be recorded.
        m.add_le(LinExpr::constant(1), 0);
        assert_eq!(m.num_constraints(), 1);
        // 0 <= 1 is trivially true and dropped.
        let mut m2 = CpModel::new();
        m2.add_le(LinExpr::constant(-1), 0);
        assert_eq!(m2.num_constraints(), 0);
    }

    #[test]
    fn violated_detects_bad_assignment() {
        let mut m = CpModel::new();
        let a = m.bool_var("a");
        let b = m.bool_var("b");
        m.add_le(LinExpr::sum([a, b]), 1);
        assert!(m.violated(&[1, 1]).is_some());
        assert!(m.violated(&[1, 0]).is_none());
    }
}
