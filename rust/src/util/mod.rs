//! In-tree utilities replacing external dev-dependencies (the build is
//! fully offline): a tiny CLI argument parser, a bench-timing harness, and
//! a deterministic property-test driver.

pub mod bench;
pub mod cli;
pub mod prop;
pub mod table;
