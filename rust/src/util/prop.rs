//! Deterministic property-test driver (proptest replacement for offline
//! builds): a splitmix64/xoshiro-style PRNG + a `for_each_case` runner that
//! reports the failing seed so cases are reproducible.

/// SplitMix64 PRNG — tiny, fast, well-distributed; good enough for test
/// case generation (NOT cryptographic).
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Self { state: seed.wrapping_add(0x9E3779B97F4A7C15) }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in `[lo, hi]` inclusive.
    pub fn int(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi);
        let span = (hi - lo) as u64 + 1;
        lo + (self.next_u64() % span) as i64
    }

    /// Uniform usize in `[lo, hi]` inclusive.
    pub fn usize(&mut self, lo: usize, hi: usize) -> usize {
        self.int(lo as i64, hi as i64) as usize
    }

    /// Uniform f64 in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    pub fn bool(&mut self) -> bool {
        self.next_u64() & 1 == 1
    }

    /// i8 across the full range (quantized tensor payloads).
    pub fn i8(&mut self) -> i8 {
        self.next_u64() as i8
    }

    /// Pick one element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.usize(0, xs.len() - 1)]
    }
}

/// Run `cases` property cases; on failure panics with the case seed so the
/// exact case can be replayed with `Rng::new(seed)`.
pub fn for_each_case(cases: u64, base_seed: u64, mut body: impl FnMut(&mut Rng)) {
    for case in 0..cases {
        let seed = base_seed ^ (case.wrapping_mul(0x9E3779B97F4A7C15));
        let mut rng = Rng::new(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| body(&mut rng)));
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!("property failed at case {case} (replay: Rng::new({seed:#x})): {msg}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_streams() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn int_respects_bounds() {
        let mut r = Rng::new(42);
        for _ in 0..10_000 {
            let v = r.int(-5, 17);
            assert!((-5..=17).contains(&v));
        }
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(1);
        for _ in 0..10_000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn failing_case_reports_seed() {
        let r = std::panic::catch_unwind(|| {
            for_each_case(10, 99, |rng| {
                assert!(rng.int(0, 10) < 100, "never fails");
                // Force a failure on a later case:
                assert!(rng.int(0, 10) <= 10);
            });
        });
        assert!(r.is_ok());
        let r2 = std::panic::catch_unwind(|| {
            for_each_case(5, 3, |_| panic!("boom"));
        });
        assert!(r2.is_err());
    }
}
