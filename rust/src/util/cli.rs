//! Minimal CLI argument parsing (`--key value` / `--flag` / positionals).

use std::collections::HashMap;

/// Parsed command line: subcommand, flags, key-value options, positionals.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub subcommand: Option<String>,
    pub options: HashMap<String, String>,
    pub flags: Vec<String>,
    pub positionals: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw arguments (excluding argv[0]).
    pub fn parse(raw: impl IntoIterator<Item = String>) -> Self {
        let mut out = Args::default();
        let mut iter = raw.into_iter().peekable();
        // First non-flag token is the subcommand.
        while let Some(tok) = iter.next() {
            if let Some(name) = tok.strip_prefix("--") {
                // `--key=value` is unambiguous; `--key value` consumes the
                // next token as the value when one is available.
                if let Some((k, v)) = name.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else {
                    match iter.peek() {
                        Some(v) if !v.starts_with("--") => {
                            let v = iter.next().unwrap();
                            out.options.insert(name.to_string(), v);
                        }
                        _ => out.flags.push(name.to_string()),
                    }
                }
            } else if out.subcommand.is_none() {
                out.subcommand = Some(tok);
            } else {
                out.positionals.push(tok);
            }
        }
        out
    }

    /// Parse from the process environment.
    pub fn from_env() -> Self {
        Self::parse(std::env::args().skip(1))
    }

    /// Option value or default.
    pub fn opt(&self, key: &str, default: &str) -> String {
        self.options.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    /// Typed option value.
    pub fn opt_parse<T: std::str::FromStr>(&self, key: &str, default: T) -> T {
        self.options
            .get(key)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    /// Was a flag passed? (A `--name value` option also counts as the flag
    /// `name` being present.)
    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name) || self.options.contains_key(name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn parses_subcommand_options_flags() {
        let a = args("compile out.bin --model yolov8n --ticks=12 --verbose");
        assert_eq!(a.subcommand.as_deref(), Some("compile"));
        assert_eq!(a.opt("model", ""), "yolov8n");
        assert_eq!(a.opt_parse("ticks", 0usize), 12);
        assert!(a.has_flag("verbose"));
        assert_eq!(a.positionals, vec!["out.bin"]);
    }

    #[test]
    fn defaults_apply() {
        let a = args("run");
        assert_eq!(a.opt("model", "mobilenet-v2"), "mobilenet-v2");
        assert_eq!(a.opt_parse("n", 7i64), 7);
        assert!(!a.has_flag("verbose"));
    }
}
