//! Minimal CLI argument parsing (`--key value` / `--flag` / positionals).

use std::collections::HashMap;

/// Parsed command line: subcommand, flags, key-value options, positionals.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub subcommand: Option<String>,
    pub options: HashMap<String, String>,
    pub flags: Vec<String>,
    pub positionals: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw arguments (excluding argv[0]).
    pub fn parse(raw: impl IntoIterator<Item = String>) -> Self {
        let mut out = Args::default();
        let mut iter = raw.into_iter().peekable();
        // First non-flag token is the subcommand.
        while let Some(tok) = iter.next() {
            if let Some(name) = tok.strip_prefix("--") {
                // `--key=value` is unambiguous; `--key value` consumes the
                // next token as the value when one is available.
                if let Some((k, v)) = name.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else {
                    match iter.peek() {
                        Some(v) if !v.starts_with("--") => {
                            let v = iter.next().unwrap();
                            out.options.insert(name.to_string(), v);
                        }
                        _ => out.flags.push(name.to_string()),
                    }
                }
            } else if out.subcommand.is_none() {
                out.subcommand = Some(tok);
            } else {
                out.positionals.push(tok);
            }
        }
        out
    }

    /// Parse from the process environment.
    pub fn from_env() -> Self {
        Self::parse(std::env::args().skip(1))
    }

    /// Option value or default.
    pub fn opt(&self, key: &str, default: &str) -> String {
        self.options.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    /// Typed option value.
    pub fn opt_parse<T: std::str::FromStr>(&self, key: &str, default: T) -> T {
        self.options
            .get(key)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    /// Was a flag passed? (A `--name value` option also counts as the flag
    /// `name` being present.)
    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name) || self.options.contains_key(name)
    }

    /// Strict typed option: missing → `default`; present but unparseable →
    /// `Err` naming the flag. Unlike [`Args::opt_parse`], a typo can never
    /// silently fall back to the default and run a different experiment.
    pub fn opt_strict<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T, String> {
        match self.options.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| format!("--{key} wants a number, got {v:?}")),
        }
    }

    /// [`Args::opt_strict`] with an inclusive lower bound on **explicit**
    /// values: degenerate input (e.g. `--max-batch 0`, `--instances 0`)
    /// is rejected with a clear error instead of panicking deep inside
    /// the scheduler. A missing flag returns `default` untouched — the
    /// bound constrains what the user typed, not the program's own
    /// default (which may use an out-of-band sentinel like 0).
    pub fn opt_strict_min<T>(&self, key: &str, default: T, min: T) -> Result<T, String>
    where
        T: std::str::FromStr + PartialOrd + std::fmt::Display,
    {
        let Some(raw) = self.options.get(key) else {
            return Ok(default);
        };
        let v: T = raw
            .parse()
            .map_err(|_| format!("--{key} wants a number, got {raw:?}"))?;
        if v < min {
            return Err(format!("--{key} must be >= {min}, got {v}"));
        }
        Ok(v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn parses_subcommand_options_flags() {
        let a = args("compile out.bin --model yolov8n --ticks=12 --verbose");
        assert_eq!(a.subcommand.as_deref(), Some("compile"));
        assert_eq!(a.opt("model", ""), "yolov8n");
        assert_eq!(a.opt_parse("ticks", 0usize), 12);
        assert!(a.has_flag("verbose"));
        assert_eq!(a.positionals, vec!["out.bin"]);
    }

    #[test]
    fn defaults_apply() {
        let a = args("run");
        assert_eq!(a.opt("model", "mobilenet-v2"), "mobilenet-v2");
        assert_eq!(a.opt_parse("n", 7i64), 7);
        assert!(!a.has_flag("verbose"));
    }

    #[test]
    fn strict_parse_rejects_garbage_instead_of_defaulting() {
        let a = args("serve --requests abc");
        // The lenient accessor silently runs the default experiment…
        assert_eq!(a.opt_parse("requests", 200usize), 200);
        // …the strict one refuses, naming the flag.
        let err = a.opt_strict("requests", 200usize).unwrap_err();
        assert!(err.contains("--requests") && err.contains("abc"), "{err}");
        // Missing flags still take the default.
        assert_eq!(a.opt_strict("seed", 7u64).unwrap(), 7);
        assert_eq!(a.opt_strict("requests", 0usize).is_ok(), false);
    }

    #[test]
    fn strict_min_rejects_degenerate_values() {
        let a = args("serve --max-batch 0 --instances 3");
        let err = a.opt_strict_min("max-batch", 1usize, 1).unwrap_err();
        assert!(err.contains("--max-batch") && err.contains(">= 1"), "{err}");
        assert_eq!(a.opt_strict_min("instances", 2usize, 1).unwrap(), 3);
        // A missing flag returns the default untouched, even when the
        // default sits below the bound (sentinel defaults like 0 stay
        // usable); garbage on a bounded flag is still a parse error.
        assert_eq!(a.opt_strict_min("queue-capacity", 0usize, 1).unwrap(), 0);
        let b = args("serve --instances nope");
        assert!(b.opt_strict_min("instances", 2usize, 1).is_err());
    }
}
