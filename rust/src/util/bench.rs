//! Benchmark timing harness (criterion replacement for offline builds).
//!
//! `harness = false` benches are plain binaries; this module gives them
//! warmup + repeated measurement with median/mean/stddev reporting so the
//! §Perf numbers in EXPERIMENTS.md are statistically meaningful.

use std::time::{Duration, Instant};

/// Result of a measured benchmark.
#[derive(Debug, Clone)]
pub struct Measurement {
    pub name: String,
    pub samples: Vec<Duration>,
}

impl Measurement {
    pub fn median(&self) -> Duration {
        let mut s = self.samples.clone();
        s.sort();
        s[s.len() / 2]
    }

    pub fn mean(&self) -> Duration {
        let total: Duration = self.samples.iter().sum();
        total / self.samples.len() as u32
    }

    pub fn stddev_us(&self) -> f64 {
        let mean = self.mean().as_secs_f64();
        let var = self
            .samples
            .iter()
            .map(|d| (d.as_secs_f64() - mean).powi(2))
            .sum::<f64>()
            / self.samples.len() as f64;
        var.sqrt() * 1e6
    }

    /// One-line report like criterion's.
    pub fn report(&self) {
        println!(
            "{:<48} median {:>12?}  mean {:>12?}  σ {:>9.1}µs  ({} samples)",
            self.name,
            self.median(),
            self.mean(),
            self.stddev_us(),
            self.samples.len()
        );
    }
}

/// Benchmark runner with warmup and sample count control.
pub struct Bencher {
    pub warmup: u32,
    pub samples: u32,
}

impl Default for Bencher {
    fn default() -> Self {
        Self { warmup: 2, samples: 10 }
    }
}

impl Bencher {
    pub fn quick() -> Self {
        Self { warmup: 1, samples: 5 }
    }

    /// Measure `f`, returning per-sample durations. The closure's return
    /// value is black-boxed to prevent the optimizer from deleting work.
    pub fn bench<T>(&self, name: &str, mut f: impl FnMut() -> T) -> Measurement {
        for _ in 0..self.warmup {
            black_box(f());
        }
        let mut samples = Vec::with_capacity(self.samples as usize);
        for _ in 0..self.samples {
            let t0 = Instant::now();
            black_box(f());
            samples.push(t0.elapsed());
        }
        let m = Measurement { name: name.to_string(), samples };
        m.report();
        m
    }
}

/// Optimization barrier (std::hint::black_box re-export point so benches
/// don't reach into std::hint directly everywhere).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_and_reports() {
        let b = Bencher { warmup: 0, samples: 3 };
        let m = b.bench("noop", || 42);
        assert_eq!(m.samples.len(), 3);
        assert!(m.median() < Duration::from_millis(10));
    }

    #[test]
    fn stddev_is_finite() {
        let b = Bencher { warmup: 0, samples: 4 };
        let m = b.bench("spin", || {
            let mut acc = 0u64;
            for i in 0..1000 {
                acc = acc.wrapping_add(i);
            }
            acc
        });
        assert!(m.stddev_us().is_finite());
    }
}
