//! Plain-text table rendering for the paper-style reports the benches and
//! the `neutron report` CLI print.

/// A simple left/right-aligned column table with a header row.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Self { headers: headers.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row width mismatch");
        self.rows.push(cells);
    }

    /// Render with column auto-sizing: first column left-aligned, the rest
    /// right-aligned (numeric convention).
    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::new();
            for i in 0..ncol {
                if i == 0 {
                    line.push_str(&format!("{:<w$}", cells[i], w = widths[i]));
                } else {
                    line.push_str(&format!("  {:>w$}", cells[i], w = widths[i]));
                }
            }
            line.push('\n');
            line
        };
        out.push_str(&fmt_row(&self.headers, &widths));
        let total: usize = widths.iter().sum::<usize>() + 2 * (ncol - 1);
        out.push_str(&"-".repeat(total));
        out.push('\n');
        for r in &self.rows {
            out.push_str(&fmt_row(r, &widths));
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Format milliseconds with one decimal like the paper's tables.
pub fn ms(v: f64) -> String {
    format!("{v:.1}")
}

/// Format a ratio like "1.8x".
pub fn ratio(v: f64) -> String {
    format!("{v:.2}x")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new(&["Model", "Latency [ms]", "LTP"]);
        t.row(vec!["MobileNet V1".into(), ms(1.0), ms(2.1)]);
        t.row(vec!["YOLOv8 N-det.".into(), ms(24.6), ms(49.2)]);
        let s = t.render();
        assert!(s.contains("MobileNet V1"));
        assert!(s.lines().count() == 4);
        // All lines same length (fixed-width rendering).
        let lens: Vec<usize> = s.lines().map(|l| l.len()).collect();
        assert!(lens.windows(2).all(|w| w[0] == w[1]), "{lens:?}");
    }

    #[test]
    #[should_panic]
    fn row_width_checked() {
        let mut t = Table::new(&["a", "b"]);
        t.row(vec!["only-one".into()]);
    }
}
