//! Offline stub of the `xla` PJRT bindings used by the runtime layer.
//!
//! [`Literal`] is a fully functional in-memory implementation (element type
//! + dims + little-endian bytes), so literal construction and inspection —
//! and every unit test that touches them — work without PJRT. The
//! client/executable surface exists so the crate compiles and links, but
//! constructing a [`PjRtClient`] returns an error: real numerics need the
//! actual PJRT bindings plus AOT artifacts, and the integration tests skip
//! gracefully when those are absent.

use std::fmt;

/// Stub error type.
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable(what: &str) -> Error {
    Error(format!(
        "{what}: PJRT unavailable (offline stub `xla` crate — build the real bindings to run numerics)"
    ))
}

/// XLA element types used by this repo's artifacts.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ElementType {
    Pred,
    S8,
    S32,
    S64,
    F32,
}

impl ElementType {
    /// Bytes per element.
    pub fn byte_size(self) -> usize {
        match self {
            ElementType::Pred | ElementType::S8 => 1,
            ElementType::S32 | ElementType::F32 => 4,
            ElementType::S64 => 8,
        }
    }
}

/// Rust scalar types with an XLA element-type mapping.
pub trait NativeType: Copy {
    const TY: ElementType;
    fn write_le(self, out: &mut Vec<u8>);
    fn read_le(bytes: &[u8]) -> Self;
}

impl NativeType for i8 {
    const TY: ElementType = ElementType::S8;
    fn write_le(self, out: &mut Vec<u8>) {
        out.push(self as u8);
    }
    fn read_le(bytes: &[u8]) -> Self {
        bytes[0] as i8
    }
}

impl NativeType for i32 {
    const TY: ElementType = ElementType::S32;
    fn write_le(self, out: &mut Vec<u8>) {
        out.extend_from_slice(&self.to_le_bytes());
    }
    fn read_le(bytes: &[u8]) -> Self {
        Self::from_le_bytes(bytes.try_into().expect("4-byte chunk"))
    }
}

impl NativeType for i64 {
    const TY: ElementType = ElementType::S64;
    fn write_le(self, out: &mut Vec<u8>) {
        out.extend_from_slice(&self.to_le_bytes());
    }
    fn read_le(bytes: &[u8]) -> Self {
        Self::from_le_bytes(bytes.try_into().expect("8-byte chunk"))
    }
}

impl NativeType for f32 {
    const TY: ElementType = ElementType::F32;
    fn write_le(self, out: &mut Vec<u8>) {
        out.extend_from_slice(&self.to_le_bytes());
    }
    fn read_le(bytes: &[u8]) -> Self {
        Self::from_le_bytes(bytes.try_into().expect("4-byte chunk"))
    }
}

/// An in-memory typed literal (the only fully working part of the stub).
#[derive(Debug, Clone, PartialEq)]
pub struct Literal {
    ty: ElementType,
    dims: Vec<usize>,
    data: Vec<u8>,
}

impl Literal {
    /// Build a literal from raw little-endian bytes.
    pub fn create_from_shape_and_untyped_data(
        ty: ElementType,
        dims: &[usize],
        data: &[u8],
    ) -> Result<Literal> {
        let expect = dims.iter().product::<usize>() * ty.byte_size();
        if data.len() != expect {
            return Err(Error(format!(
                "shape {dims:?} of {ty:?} needs {expect} bytes, got {}",
                data.len()
            )));
        }
        Ok(Literal { ty, dims: dims.to_vec(), data: data.to_vec() })
    }

    /// Build a rank-1 literal from a typed slice.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        let mut bytes = Vec::with_capacity(data.len() * T::TY.byte_size());
        for &v in data {
            v.write_le(&mut bytes);
        }
        Literal { ty: T::TY, dims: vec![data.len()], data: bytes }
    }

    /// Read the payload back as a typed vector.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        if T::TY != self.ty {
            return Err(Error(format!(
                "literal holds {:?}, requested {:?}",
                self.ty,
                T::TY
            )));
        }
        Ok(self
            .data
            .chunks_exact(self.ty.byte_size())
            .map(T::read_le)
            .collect())
    }

    /// Total element count.
    pub fn element_count(&self) -> usize {
        self.dims.iter().product()
    }

    /// Element type.
    pub fn ty(&self) -> Result<ElementType> {
        Ok(self.ty)
    }

    /// Dimensions.
    pub fn dims(&self) -> &[usize] {
        &self.dims
    }

    /// Tuple decomposition — stub literals are never tuples, so this yields
    /// an empty vector and callers fall back to the literal itself.
    pub fn decompose_tuple(&mut self) -> Result<Vec<Literal>> {
        Ok(Vec::new())
    }
}

impl AsRef<Literal> for Literal {
    fn as_ref(&self) -> &Literal {
        self
    }
}

/// Stub PJRT client — construction always fails offline.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("PjRtClient::compile"))
    }
}

/// Stub HLO module proto.
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(unavailable("HloModuleProto::from_text_file"))
    }
}

/// Stub computation wrapper.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Stub loaded executable.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T: AsRef<Literal>>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PjRtLoadedExecutable::execute"))
    }
}

/// Stub device buffer.
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("PjRtBuffer::to_literal_sync"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_i8_and_i32() {
        let l = Literal::create_from_shape_and_untyped_data(
            ElementType::S8,
            &[2, 3],
            &[1, 2, 3, 0xFF, 5, 6],
        )
        .unwrap();
        assert_eq!(l.element_count(), 6);
        assert_eq!(l.ty().unwrap(), ElementType::S8);
        assert_eq!(l.to_vec::<i8>().unwrap(), vec![1, 2, 3, -1, 5, 6]);

        let v = Literal::vec1(&[10i32, -20, 30]);
        assert_eq!(v.dims(), &[3]);
        assert_eq!(v.to_vec::<i32>().unwrap(), vec![10, -20, 30]);
    }

    #[test]
    fn wrong_type_or_size_errors() {
        let l = Literal::vec1(&[1i32, 2]);
        assert!(l.to_vec::<i64>().is_err());
        assert!(Literal::create_from_shape_and_untyped_data(ElementType::S32, &[2], &[0u8; 3])
            .is_err());
    }

    #[test]
    fn client_is_unavailable_offline() {
        let e = PjRtClient::cpu().err().unwrap();
        assert!(e.to_string().contains("PJRT unavailable"));
    }

    #[test]
    fn decompose_tuple_is_empty() {
        let mut l = Literal::vec1(&[1i8]);
        assert!(l.decompose_tuple().unwrap().is_empty());
    }
}
