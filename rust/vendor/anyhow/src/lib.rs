//! Minimal offline stand-in for the `anyhow` error-handling crate.
//!
//! The build is fully offline (no registry access), so the workspace
//! vendors the small subset of `anyhow` this codebase uses: [`Error`],
//! [`Result`], [`Context`] on `Result`/`Option`, and the `anyhow!`/`bail!`
//! macros. The subset is API-compatible with the real crate; swap the
//! workspace path dependency for crates.io `anyhow` to upgrade.

use std::fmt;

/// `Result<T, anyhow::Error>`.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A dynamic error: a rendered message plus the source it wraps, if any.
///
/// Like the real `anyhow::Error`, this type deliberately does NOT implement
/// `std::error::Error` — that is what makes the blanket `From` impl below
/// coherent.
pub struct Error {
    msg: String,
    source: Option<Box<dyn std::error::Error + Send + Sync + 'static>>,
}

impl Error {
    /// Create an error from a displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Self { msg: message.to_string(), source: None }
    }

    /// Prepend an outer context message (used by [`Context`]).
    fn wrap<C: fmt::Display>(self, context: C) -> Self {
        Self { msg: format!("{context}: {}", self.msg), source: self.source }
    }

    /// The wrapped source error, if this error was converted from one.
    pub fn source(&self) -> Option<&(dyn std::error::Error + Send + Sync + 'static)> {
        self.source.as_deref()
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)?;
        let mut src = self.source.as_deref().and_then(|e| e.source());
        while let Some(s) = src {
            write!(f, "\n\nCaused by:\n    {s}")?;
            src = s.source();
        }
        Ok(())
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        Self { msg: e.to_string(), source: Some(Box::new(e)) }
    }
}

/// Context-attachment extension for `Result` and `Option`.
pub trait Context<T> {
    /// Attach a context message to the error/None case.
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T>;
    /// Attach a lazily-built context message to the error/None case.
    fn with_context<C: fmt::Display + Send + Sync + 'static, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().wrap(context))
    }

    fn with_context<C: fmt::Display + Send + Sync + 'static, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T> {
        self.map_err(|e| e.into().wrap(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display + Send + Sync + 'static, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Build an [`Error`] from a format string or a displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Early-return with an [`Error`] built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*).into())
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Result<i32> {
        let n: i32 = s.parse()?;
        if n < 0 {
            bail!("negative value {n}");
        }
        Ok(n)
    }

    #[test]
    fn question_mark_converts_std_errors() {
        assert_eq!(parse("42").unwrap(), 42);
        let e = parse("nope").unwrap_err();
        assert!(e.to_string().contains("invalid digit"));
        assert!(e.source().is_some());
    }

    #[test]
    fn bail_formats_inline_captures() {
        let e = parse("-3").unwrap_err();
        assert_eq!(e.to_string(), "negative value -3");
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), std::io::Error> =
            Err(std::io::Error::new(std::io::ErrorKind::NotFound, "gone"));
        let e = r.context("reading manifest").unwrap_err();
        assert_eq!(e.to_string(), "reading manifest: gone");

        let o: Option<u8> = None;
        let e = o.with_context(|| format!("key {} missing", "x")).unwrap_err();
        assert_eq!(e.to_string(), "key x missing");
        assert!(Some(5u8).context("fine").is_ok());
    }

    #[test]
    fn debug_prints_cause_chain() {
        let inner = std::io::Error::new(std::io::ErrorKind::Other, "root cause");
        let e: Error = inner.into();
        let dbg = format!("{e:?}");
        assert!(dbg.contains("root cause"));
    }
}
