//! Bench: regenerate Fig. 4 — decoupled access-execute pipeline vs the
//! monolithic (serialized) pipeline, per model + ASCII tick timeline.

fn main() {
    eiq_neutron::report::fig4();
}
