//! Bench: regenerate Table II — CP problem partitioning vs compilation
//! time and inference time on YOLOv8N-det (pass --quick for MobileNetV2).

use eiq_neutron::util::cli::Args;

fn main() {
    let args = Args::parse(std::env::args().skip(1));
    let quick = args.has_flag("quick");
    eiq_neutron::report::table2(quick);
}
