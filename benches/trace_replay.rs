//! Bench: trace capture/replay overhead and calibration reporting —
//! recording cost vs plain serving, JSONL serialize/parse throughput,
//! replay cost with cold and warm compile caches, and the per-op-class
//! predicted-vs-observed calibration table for the recorded workload.

use eiq_neutron::arch::NeutronConfig;
use eiq_neutron::serve::{serve_with_cache, CompileCache, SchedulerOptions, ServeOptions};
use eiq_neutron::trace::{serve_recorded, ReplayDriver, Trace, ValidationReport};
use eiq_neutron::util::bench::Bencher;

fn main() {
    let cfg = NeutronConfig::flagship_2tops();
    let opts = ServeOptions {
        requests: 200,
        scheduler: SchedulerOptions {
            instances: 2,
            max_batch: 4,
            dynamic_batch: true,
            ..SchedulerOptions::default()
        },
        ..ServeOptions::default()
    };
    let b = Bencher::quick();

    // Recording overhead: same scenario with and without the recorder,
    // both on warm caches so the delta is pure observation cost.
    let mut warm = CompileCache::for_serving(cfg.clone());
    for &model in &opts.models {
        warm.get(model);
    }
    b.bench("serve 200 req (warm cache, no recording)", || {
        serve_with_cache(&cfg, &opts, &mut warm).goodput_inf_s
    });
    b.bench("serve 200 req (warm cache, recording)", || {
        serve_recorded(&cfg, &opts, &mut warm).0.goodput_inf_s
    });

    // One canonical recording for the format + replay benches (fresh
    // cache: the bit-identical-replay configuration).
    let mut fresh = CompileCache::for_serving(cfg.clone());
    let (report, trace) = serve_recorded(&cfg, &opts, &mut fresh);
    let jsonl = trace.to_jsonl();
    println!(
        "\ntrace: {} requests, {} completions, {} model profiles, {} lines, {} KiB",
        trace.requests.len(),
        trace.completions.len(),
        trace.model_ops.len(),
        jsonl.lines().count(),
        jsonl.len() / 1024
    );

    b.bench("serialize trace to JSONL", || trace.to_jsonl().len());
    b.bench("parse JSONL trace", || Trace::parse(&jsonl).unwrap().requests.len());

    let driver = ReplayDriver::from_jsonl(&jsonl).expect("recorded trace parses");
    b.bench("replay 200-req trace (cold cache)", || {
        driver.replay(&cfg).unwrap().report.goodput_inf_s
    });
    b.bench("replay 200-req trace (warm cache)", || {
        driver.replay_with_cache(&cfg, &mut warm).unwrap().report.goodput_inf_s
    });

    let replayed = driver.replay(&cfg).expect("replay");
    assert!(replayed.matches_recording(), "bench trace must replay exactly");
    assert_eq!(replayed.report, report, "replayed report must be bit-identical");
    println!("\nreplayed report matches the recording bit-for-bit:\n{}", report.summary());

    println!("timing-model calibration over the recorded workload:");
    let validation = ValidationReport::from_trace(&trace).expect("trace has op profiles");
    print!("{}", validation.table());
}
