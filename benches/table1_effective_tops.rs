//! Bench: regenerate Table I (effective TOPS of the eNPU/iNPU baselines on
//! ResNet50V1 and EfficientNet-Lite0) and time the baseline estimators.

use eiq_neutron::baselines::{enpu, inpu, EnpuConfig, InpuConfig};
use eiq_neutron::util::bench::Bencher;
use eiq_neutron::zoo::ModelId;

fn main() {
    eiq_neutron::report::table1();

    println!("\n-- harness timings --");
    let b = Bencher::default();
    let resnet = ModelId::ResNet50V1.build();
    let effnet = ModelId::EfficientNetLite0.build();
    let e = EnpuConfig::enpu_b();
    let i = InpuConfig::vision_11tops();
    b.bench("enpu::estimate(resnet50)", || enpu::estimate(&resnet, &e).latency_ms);
    b.bench("enpu::estimate(efficientnet)", || enpu::estimate(&effnet, &e).latency_ms);
    b.bench("inpu::estimate(resnet50)", || inpu::estimate(&resnet, &i).latency_ms);
    b.bench("inpu::estimate(efficientnet)", || inpu::estimate(&effnet, &i).latency_ms);
}
