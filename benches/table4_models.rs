//! Bench: regenerate Table IV — model characteristics (GMACs, M params)
//! for every benchmark model, vs the paper's reported values.

use eiq_neutron::util::bench::Bencher;
use eiq_neutron::zoo::ModelId;

fn main() {
    eiq_neutron::report::table4();

    println!("\n-- harness timings (graph construction) --");
    let b = Bencher::default();
    for id in [ModelId::MobileNetV2, ModelId::YoloV8s, ModelId::EfficientDetLite0] {
        b.bench(&format!("build {}", id.display_name()), || id.build().ops.len());
    }
}
