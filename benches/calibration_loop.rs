//! Bench: the calibration feedback loop across zoo models — per-model
//! inference estimate and per-op MAPE before vs after one fit→recompile
//! iteration, plus the wall-clock cost of a full `neutron tune` pass
//! (fit + recompile + replay) over a recorded multi-tenant trace.

use eiq_neutron::arch::NeutronConfig;
use eiq_neutron::ir::OpClass;
use eiq_neutron::serve::{CompileCache, SchedulerOptions, ServeOptions};
use eiq_neutron::trace::{
    profile_model_ops, serve_recorded, tune_from_trace, OpRecord, ValidationReport,
};
use eiq_neutron::util::bench::Bencher;
use eiq_neutron::util::table::Table;
use eiq_neutron::zoo::ModelId;

fn pairs(records: &[OpRecord]) -> Vec<(OpClass, u64, u64)> {
    records
        .iter()
        .map(|o| (o.class, o.predicted_cycles, o.observed_cycles))
        .collect()
}

fn main() {
    let cfg = NeutronConfig::flagship_2tops();
    let models = [
        ModelId::MobileNetV3Min,
        ModelId::MobileNetV1,
        ModelId::MobileNetV2,
        ModelId::EfficientNetLite0,
        ModelId::ResNet50V1,
    ];

    // Per model: fit a guarded calibration from the model's own
    // predicted-vs-observed profile, recompile under it, and compare the
    // cost model's accuracy and the artifact's inference estimate.
    let mut base = CompileCache::for_serving(cfg.clone());
    let mut t = Table::new(&[
        "model",
        "inf ms",
        "inf ms (cal)",
        "MAPE %",
        "MAPE % (cal)",
        "fitted classes",
    ]);
    for &model in &models {
        let entry = base.get(model);
        let before = ValidationReport::from_pairs(&pairs(&profile_model_ops(&cfg, &entry)));
        let cal = before.calibration_guarded();
        let mut tuned_cache = CompileCache::for_serving_with(cfg.clone(), cal.clone());
        let tuned = tuned_cache.get(model);
        let after = ValidationReport::from_pairs(&pairs(&profile_model_ops(&cfg, &tuned)));
        t.row(vec![
            model.display_name().to_string(),
            format!("{:.3}", entry.compiled.inference_ms),
            format!("{:.3}", tuned.compiled.inference_ms),
            format!("{:.1}", before.overall_mape_pct),
            format!("{:.1}", after.overall_mape_pct),
            cal.scales().len().to_string(),
        ]);
    }
    println!("one fit→recompile iteration per model (guarded, clamped fits):");
    print!("{}", t.render());
    println!(
        "note: the calibrated inference estimate re-prices the virtual clock with the\n\
         corrections folded in — it is the honest (higher) number, not a slowdown.\n"
    );

    // Wall-clock of the full closed loop over a recorded serving trace.
    let opts = ServeOptions {
        requests: 64,
        scheduler: SchedulerOptions { instances: 2, ..SchedulerOptions::default() },
        ..ServeOptions::default()
    };
    let mut fresh = CompileCache::for_serving(cfg.clone());
    let (_, trace) = serve_recorded(&cfg, &opts, &mut fresh);
    let b = Bencher::quick();
    b.bench("tune iteration (fit + recompile + replay, 64 req)", || {
        tune_from_trace(&cfg, &trace).unwrap().mape_after_pct()
    });

    let outcome = tune_from_trace(&cfg, &trace).expect("recorded trace tunes");
    println!("\n{}", outcome.table());
}
