//! Bench: autoregressive decode serving — prefill/decode split, KV-cache
//! residency and continuous batching. The continuous-batching sweep is
//! the acceptance evidence for the GenAI scheduler: at every offered
//! load, keeping decode weights pinned across admitted sequences must
//! strictly cut both the makespan and the mean TPOT against
//! request-boundary replay of the same trace (asserted, not just
//! reported). The residency rows show the KV-cache side: with TCM
//! residency on, decode steps re-stream fewer KV bytes from DDR.
//!
//! `--json PATH` additionally writes the measurements and sweep rows as
//! a JSON array (used by ci.sh to emit `BENCH_genai_decode.json`).

use eiq_neutron::arch::NeutronConfig;
use eiq_neutron::serve::{serve_with_cache, CompileCache, SchedulerOptions, ServeOptions};
use eiq_neutron::util::bench::{Bencher, Measurement};
use eiq_neutron::zoo::ModelId;

fn decode_opts(gap: u64, scheduler: SchedulerOptions) -> ServeOptions {
    ServeOptions {
        models: vec![ModelId::GptTiny],
        requests: 48,
        mean_gap_cycles: gap,
        seed: 17,
        scheduler,
        decode: true,
        prompt_tokens: 6,
        decode_tokens: 8,
        max_context: 16,
        ..ServeOptions::default()
    }
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let json_path = args
        .iter()
        .position(|a| a == "--json")
        .and_then(|i| args.get(i + 1))
        .cloned();

    let cfg = NeutronConfig::flagship_2tops();
    let b = Bencher::quick();
    let mut results: Vec<Measurement> = Vec::new();
    let mut extra_json: Vec<String> = Vec::new();

    // Warm cache shared by the whole bench: the decode bucket ladder
    // compiles once, every row after that is pure scheduling.
    let mut cache = CompileCache::for_serving(cfg.clone());
    let warm = decode_opts(200_000, SchedulerOptions { instances: 1, ..Default::default() });
    serve_with_cache(&cfg, &warm, &mut cache);
    results.push(b.bench("decode serve 48 req, warm ladder, 1 instance", || {
        serve_with_cache(&cfg, &warm, &mut cache).tokens_per_s
    }));

    // Continuous vs request-boundary sweep: same trace, same instance
    // count, only the batching regime differs. The gap ramps from idle
    // (every sequence runs alone) to saturated (deep decode backlog);
    // the pinned-weights win must be strict at every point.
    println!("continuous batching sweep: 48 decode requests, prompt 6 + 8 tokens, 1 instance");
    println!(
        "{:>9}  {:<16} {:>14} {:>9} {:>11} {:>11} {:>11}",
        "gap cyc", "regime", "makespan cyc", "tok/s", "TTFT p50", "TTFT p99", "TPOT mean"
    );
    for gap in [800_000u64, 200_000, 50_000] {
        let rb = serve_with_cache(
            &cfg,
            &decode_opts(gap, SchedulerOptions { instances: 1, ..Default::default() }),
            &mut cache,
        );
        let cb = serve_with_cache(
            &cfg,
            &decode_opts(
                gap,
                SchedulerOptions { instances: 1, continuous_batch: true, ..Default::default() },
            ),
            &mut cache,
        );
        assert_eq!(rb.completed, cb.completed);
        assert_eq!(rb.tokens_generated, cb.tokens_generated);
        assert!(
            cb.makespan_cycles < rb.makespan_cycles,
            "gap {gap}: continuous batching must strictly cut the makespan \
             ({} !< {})",
            cb.makespan_cycles,
            rb.makespan_cycles
        );
        assert!(
            cb.tpot_mean_ms < rb.tpot_mean_ms,
            "gap {gap}: continuous batching must strictly cut mean TPOT \
             ({} !< {})",
            cb.tpot_mean_ms,
            rb.tpot_mean_ms
        );
        assert!(
            cb.ttft_p50_ms <= rb.ttft_p50_ms,
            "gap {gap}: continuous batching must never regress TTFT"
        );
        for (name, continuous, r) in
            [("request-boundary", false, &rb), ("continuous", true, &cb)]
        {
            println!(
                "{:>9}  {:<16} {:>14} {:>9.1} {:>8.3} ms {:>8.3} ms {:>8.3} ms",
                gap,
                name,
                r.makespan_cycles,
                r.tokens_per_s,
                r.ttft_p50_ms,
                r.ttft_p99_ms,
                r.tpot_mean_ms
            );
            extra_json.push(format!(
                "{{\"name\":\"decode_sweep_gap{}_{}\",\"continuous_batch\":{},\
                 \"makespan_cycles\":{},\"tokens_per_s\":{},\"ttft_p50_ms\":{},\
                 \"ttft_p99_ms\":{},\"tpot_mean_ms\":{},\"tokens_generated\":{}}}",
                gap,
                if continuous { "continuous" } else { "request_boundary" },
                continuous,
                r.makespan_cycles,
                r.tokens_per_s,
                r.ttft_p50_ms,
                r.ttft_p99_ms,
                r.tpot_mean_ms,
                r.tokens_generated
            ));
        }
    }

    // KV residency: same saturated decode trace, with and without TCM
    // weight+KV residency. Resident KV caches skip the DDR re-stream on
    // decode steps whose cache survived in TCM since the previous step.
    println!("\nKV residency: 48 decode requests, saturated arrivals, 1 instance");
    for (name, weight_residency) in [("ddr-every-step", false), ("tcm-resident", true)] {
        let r = serve_with_cache(
            &cfg,
            &decode_opts(
                50_000,
                SchedulerOptions {
                    instances: 1,
                    weight_residency,
                    continuous_batch: true,
                    ..Default::default()
                },
            ),
            &mut cache,
        );
        println!(
            "  {:<16} makespan {:>14} cyc  {:>7.1} tok/s  {} residency hit(s)  {} eviction(s)",
            name, r.makespan_cycles, r.tokens_per_s, r.residency_hits, r.kv_evictions
        );
        extra_json.push(format!(
            "{{\"name\":\"decode_kv_residency_{}\",\"weight_residency\":{},\
             \"makespan_cycles\":{},\"tokens_per_s\":{},\"kv_evictions\":{}}}",
            name, weight_residency, r.makespan_cycles, r.tokens_per_s, r.kv_evictions
        ));
    }

    let report = serve_with_cache(
        &cfg,
        &decode_opts(
            200_000,
            SchedulerOptions { instances: 1, continuous_batch: true, ..Default::default() },
        ),
        &mut cache,
    );
    println!("\n{}", report.summary());

    if let Some(path) = json_path {
        let mut rows: Vec<String> = results
            .iter()
            .map(|m| {
                format!(
                    "{{\"name\":{:?},\"median_us\":{:.1},\"mean_us\":{:.1},\"stddev_us\":{:.1}}}",
                    m.name,
                    m.median().as_secs_f64() * 1e6,
                    m.mean().as_secs_f64() * 1e6,
                    m.stddev_us()
                )
            })
            .collect();
        rows.extend(extra_json);
        let json = format!("[\n  {}\n]\n", rows.join(",\n  "));
        std::fs::write(&path, json).expect("write bench JSON");
        eprintln!("wrote {path}");
    }
}
