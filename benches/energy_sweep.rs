//! Bench: the energy accounting subsystem (PR 9) — meter overhead,
//! the race-to-idle vs stretch Pareto points, budget shedding under a
//! draining joule budget, and the analytic J/inference table for the
//! zoo. The race/stretch comparison is the acceptance evidence for
//! energy-aware scheduling: the two modes must land on *different*
//! (makespan, joules) points — stretch strictly serializes work
//! (makespan up) while eliding follower parameter-fetch DMA (DMA
//! joules down) — so neither dominates and the knob is a real policy
//! choice, not a no-op.
//!
//! `--json PATH` additionally writes the measurements and the sweep rows
//! as a JSON array (used by ci.sh to emit `BENCH_energy_sweep.json`).

use eiq_neutron::arch::NeutronConfig;
use eiq_neutron::energy::{fj_to_joules, EnergyChannel, EnergyMode, EnergyModel};
use eiq_neutron::serve::{
    serve_with_cache, CompileCache, Priority, PriorityMix, Request, Scheduler, SchedulerOptions,
    ServeOptions,
};
use eiq_neutron::util::bench::{Bencher, Measurement};
use eiq_neutron::zoo::ModelId;

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let json_path = args
        .iter()
        .position(|a| a == "--json")
        .and_then(|i| args.get(i + 1))
        .cloned();

    let cfg = NeutronConfig::flagship_2tops();
    let b = Bencher::quick();
    let mut results: Vec<Measurement> = Vec::new();
    let mut extra_json: Vec<String> = Vec::new();

    // Meter overhead: the same warm-cache workload with the meter off vs
    // on. Pricing is pure observation on the tick walk, so the overhead
    // should be small and the timing identical (asserted below in the
    // race/stretch sweep via the scheduler clocks).
    let mut cache = CompileCache::for_serving(cfg.clone());
    let base = ServeOptions::default();
    for &model in &base.models {
        cache.get(model);
    }
    for (name, energy) in [("meter off", false), ("meter on", true)] {
        let o = ServeOptions {
            scheduler: SchedulerOptions { energy, ..base.scheduler.clone() },
            ..base.clone()
        };
        results.push(b.bench(&format!("serve 200 req warm cache, {name}"), || {
            serve_with_cache(&cfg, &o, &mut cache).goodput_inf_s
        }));
    }

    // Race-to-idle vs stretch: one instance per request, all arrivals at
    // t=0, one hot model. Race always finds an idle peer (or an empty
    // queue), so every dispatch is solo and the fleet finishes in one
    // service time; stretch coalesces everything into one batch whose
    // followers skip their parameter fetches. Driven through the
    // Scheduler directly so both runs replay the identical compiled
    // program and the comparison is pure policy.
    println!("race-to-idle vs stretch: 6 requests at t=0, 6 instances, mobilenet-v2");
    println!(
        "{:>12}  {:>14} {:>12} {:>12} {:>12} {:>8}",
        "mode", "makespan cyc", "total J", "dma J", "idle J", "batched"
    );
    let program = cache.get(ModelId::MobileNetV2).program.clone();
    let run = |mode: EnergyMode| {
        let opts = SchedulerOptions {
            instances: 6,
            max_batch: 6,
            energy: true,
            energy_mode: mode,
            ..SchedulerOptions::default()
        };
        let mut s = Scheduler::new(&cfg, &opts);
        for id in 0..6 {
            s.admit(Request {
                id,
                model: ModelId::MobileNetV2,
                priority: Priority::Standard,
                arrival_cycles: 0,
                prompt_tokens: 0,
                decode_tokens: 0,
            });
        }
        let mut done = Vec::new();
        while s.next_model().is_some() {
            done.extend(s.dispatch_next(ModelId::MobileNetV2, &program));
        }
        let dma: u64 = done.iter().map(|c| c.energy_dma_fj).sum();
        let idle: u64 = done.iter().map(|c| c.energy_idle_fj).sum();
        let batched = done.iter().filter(|c| c.batch_index > 0).count();
        (s.makespan_cycles(), s.energy_spent_fj(), dma, idle, batched)
    };
    let race = run(EnergyMode::RaceToIdle);
    let stretch = run(EnergyMode::Stretch);
    for (name, r) in [("race-to-idle", &race), ("stretch", &stretch)] {
        println!(
            "{:>12}  {:>14} {:>12.6} {:>12.6} {:>12.6} {:>8}",
            name,
            r.0,
            fj_to_joules(r.1),
            fj_to_joules(r.2),
            fj_to_joules(r.3),
            r.4
        );
        extra_json.push(format!(
            "{{\"name\":\"energy_mode_{}\",\"makespan_cycles\":{},\"total_fj\":{},\
             \"dma_fj\":{},\"idle_fj\":{},\"batched\":{}}}",
            name, r.0, r.1, r.2, r.3, r.4
        ));
    }
    assert_eq!(race.4, 0, "race-to-idle must not batch with idle instances available");
    assert!(stretch.4 > 0, "stretch must coalesce followers");
    assert!(
        stretch.0 > race.0,
        "stretch serializes work: makespan {} vs {}",
        stretch.0,
        race.0
    );
    assert!(
        stretch.2 < race.2,
        "stretch elides follower parameter-fetch DMA: {} vs {} fJ",
        stretch.2,
        race.2
    );
    assert!(
        (race.0, race.1) != (stretch.0, stretch.1),
        "the two modes must reach different (makespan, joules) points"
    );

    // Budget sweep: the same overload trace under a draining joule
    // budget. An unbounded budget sheds nothing; a binding one sheds
    // Batch first, then Standard, never Realtime — goodput degrades
    // class by class instead of collapsing.
    println!("\nenergy budget sweep: 120 requests, 2 instances, mobilenet-v1, seed 21");
    println!(
        "{:>12}  {:>9} {:>6} {:>12} {:>14}",
        "budget J", "completed", "shed", "spent J", "J/inference"
    );
    let free = {
        let o = budget_options(None);
        serve_with_cache(&cfg, &o, &mut cache)
    };
    assert_eq!(free.shed, 0, "no budget, no energy shedding");
    let budgets = [
        None,
        Some(free.energy_total_fj / 2),
        Some(free.energy_total_fj / 4),
        Some(free.energy_total_fj / 8),
    ];
    let mut prev_completed = u64::MAX;
    for budget in budgets {
        let o = budget_options(budget);
        let r = serve_with_cache(&cfg, &o, &mut cache);
        assert_eq!(
            r.energy_compute_fj + r.energy_dma_fj + r.energy_idle_fj,
            r.energy_total_fj,
            "conservation must hold under shedding"
        );
        println!(
            "{:>12}  {:>9} {:>6} {:>12.6} {:>14.9}",
            budget.map_or("unbounded".to_string(), |b| format!("{:.4}", fj_to_joules(b))),
            r.completed,
            r.shed,
            fj_to_joules(r.energy_total_fj),
            r.joules_per_inference
        );
        extra_json.push(format!(
            "{{\"name\":\"energy_budget\",\"budget_fj\":{},\"completed\":{},\"shed\":{},\
             \"energy_total_fj\":{},\"joules_per_inference\":{}}}",
            budget.unwrap_or(0),
            r.completed,
            r.shed,
            r.energy_total_fj,
            r.joules_per_inference
        ));
        assert!(
            r.completed <= prev_completed,
            "a tighter budget must not complete more work"
        );
        prev_completed = r.completed;
    }

    // Analytic J/inference table for the zoo — the same
    // `EnergyModel::predict_inference` the calibration loop scores and
    // `neutron list --energy-calibration` prints.
    println!("\nanalytic J/inference (uncalibrated):");
    let model = EnergyModel::for_config(&cfg);
    for id in ModelId::all() {
        let g = id.build();
        let p = model.predict_inference(&cfg, g.total_macs(), g.total_params());
        let total = EnergyChannel::all()
            .into_iter()
            .map(|c| match c {
                EnergyChannel::Compute => p.compute_fj,
                EnergyChannel::Dma => p.dma_fj,
                EnergyChannel::Idle => p.idle_fj,
            })
            .sum::<u64>();
        println!("{:<22} {:>12.6} J/inf", id.display_name(), fj_to_joules(total));
        extra_json.push(format!(
            "{{\"name\":\"predicted_j_per_inf_{}\",\"total_fj\":{}}}",
            id.slug(),
            total
        ));
    }

    if let Some(path) = json_path {
        let mut rows: Vec<String> = results
            .iter()
            .map(|m| {
                format!(
                    "{{\"name\":{:?},\"median_us\":{:.1},\"mean_us\":{:.1},\"stddev_us\":{:.1}}}",
                    m.name,
                    m.median().as_secs_f64() * 1e6,
                    m.mean().as_secs_f64() * 1e6,
                    m.stddev_us()
                )
            })
            .collect();
        rows.extend(extra_json);
        let json = format!("[\n  {}\n]\n", rows.join(",\n  "));
        std::fs::write(&path, json).expect("write bench JSON");
        eprintln!("wrote {path}");
    }
}

/// The budget sweep's fixed workload: overloaded enough that a binding
/// budget has traffic left to shed when it drains.
fn budget_options(energy_budget_fj: Option<u64>) -> ServeOptions {
    ServeOptions {
        models: vec![ModelId::MobileNetV1],
        requests: 120,
        mean_gap_cycles: 100_000,
        seed: 21,
        priority_mix: PriorityMix { realtime: 1, standard: 1, batch: 1 },
        scheduler: SchedulerOptions {
            instances: 2,
            energy: true,
            energy_budget_fj,
            ..SchedulerOptions::default()
        },
        ..ServeOptions::default()
    }
}
