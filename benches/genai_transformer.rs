//! Bench: the Sec. VI Gen-AI claim — decoder-only transformer GEMMs on the
//! 2-TOPS NPU vs 4×Cortex-A55 at 1.8 GHz (paper: ~10× speedup).

fn main() {
    eiq_neutron::report::genai();
}
