//! Bench: serving throughput under load — compile-cache cold vs warm,
//! instance scaling, the overload sweep (offered load vs goodput and
//! tail latency with shedding and batching), and the pipelining ×
//! residency sweep (PR 7). The overload sweep is the acceptance evidence
//! for the overload-aware scheduler: goodput saturates (instead of
//! collapsing) past the knee with shedding on, and batching buys extra
//! goodput at the same offered load. The pipelining × residency sweep is
//! the acceptance evidence for intra-instance pipelining + TCM weight
//! residency: with either knob on, the makespan of a standard-only
//! unbatched trace never exceeds the baseline's (asserted), and the
//! hidden overlap cycles / residency hit-rate are reported.
//!
//! `--json PATH` additionally writes the measurements and the sweep rows
//! as a JSON array (used by ci.sh to emit `BENCH_serve_throughput.json`).

use eiq_neutron::arch::NeutronConfig;
use eiq_neutron::serve::{
    serve, serve_with_cache, AdmissionPolicy, CompileCache, PriorityMix, SchedulerOptions,
    ServeOptions,
};
use eiq_neutron::util::bench::{Bencher, Measurement};

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let json_path = args
        .iter()
        .position(|a| a == "--json")
        .and_then(|i| args.get(i + 1))
        .cloned();

    let cfg = NeutronConfig::flagship_2tops();
    let opts = ServeOptions::default();
    let b = Bencher::quick();
    let mut results: Vec<Measurement> = Vec::new();
    let mut extra_json: Vec<String> = Vec::new();

    // Cold cache: every sample pays the full CP compile for each model.
    results.push(b.bench("serve 200 req / 3 models, cold cache", || {
        serve(&cfg, &opts).goodput_inf_s
    }));

    // Warm cache: compiles amortized away; scaling is pure scheduling.
    let mut cache = CompileCache::for_serving(cfg.clone());
    for &model in &opts.models {
        cache.get(model);
    }
    for instances in [1usize, 2, 4, 8] {
        let o = ServeOptions {
            scheduler: SchedulerOptions { instances, ..opts.scheduler.clone() },
            ..opts.clone()
        };
        results.push(b.bench(&format!("serve 200 req warm cache, {instances} instance(s)"), || {
            serve_with_cache(&cfg, &o, &mut cache).goodput_inf_s
        }));
    }

    // Overload sweep: a fixed 2-instance fleet while the offered load ramps
    // from under the service knee to ~8× past it (the mean gap halves every
    // row). Three scheduler shapes per load point:
    //   unbounded   — the PR-1 queue: nothing sheds, queueing delay (and
    //                 p99) grows with the backlog;
    //   shed        — queue capacity 16, reject-newest: goodput saturates
    //                 at the service rate and p99 stays bounded;
    //   shed+batch  — same, plus same-model batching (max_batch 8):
    //                 followers skip parameter fetches, so the saturated
    //                 goodput rises above the unbatched ceiling.
    println!("\noverload sweep: 400 requests, 2 instances, 3 models, seed 7");
    println!(
        "{:>9}  {:<11} {:>10} {:>10} {:>7} {:>10} {:>10} {:>8}",
        "gap cyc", "scheduler", "offered/s", "goodput/s", "shed%", "p50 ms", "p99 ms", "batched"
    );
    for gap in [1_200_000u64, 600_000, 300_000, 150_000, 75_000] {
        let shapes: [(&str, SchedulerOptions); 3] = [
            ("unbounded", SchedulerOptions { instances: 2, ..SchedulerOptions::default() }),
            (
                "shed",
                SchedulerOptions {
                    instances: 2,
                    queue_capacity: Some(16),
                    policy: AdmissionPolicy::RejectNewest,
                    ..SchedulerOptions::default()
                },
            ),
            (
                "shed+batch",
                SchedulerOptions {
                    instances: 2,
                    queue_capacity: Some(16),
                    policy: AdmissionPolicy::RejectNewest,
                    max_batch: 8,
                    ..SchedulerOptions::default()
                },
            ),
        ];
        for (name, scheduler) in shapes {
            let o = ServeOptions {
                requests: 400,
                mean_gap_cycles: gap,
                scheduler,
                ..ServeOptions::default()
            };
            let r = serve_with_cache(&cfg, &o, &mut cache);
            println!(
                "{:>9}  {:<11} {:>10.1} {:>10.1} {:>6.1}% {:>10.3} {:>10.3} {:>8}",
                gap,
                name,
                r.offered_load_inf_s,
                r.goodput_inf_s,
                r.shed_rate() * 100.0,
                r.p50_ms,
                r.p99_ms,
                r.batched_requests
            );
        }
    }

    // Pipelining × residency sweep (PR 7): one hot model, standard-only
    // traffic, unbounded queue, no batching — the shape for which the
    // makespan-monotonicity property holds (see the differential suite),
    // so the baseline comparison is an assertion, not just a report.
    println!("\npipelining × residency sweep: 300 requests, 2 instances, 1 model, seed 13");
    println!(
        "{:>14}  {:>14} {:>10} {:>10} {:>11} {:>9} {:>6}",
        "scheduler", "makespan cyc", "goodput/s", "p99 ms", "overlap cyc", "res hit%", "warm"
    );
    let combos: [(&str, bool, bool, bool); 5] = [
        ("baseline", false, false, false),
        ("pipeline", true, false, false),
        ("residency", false, true, false),
        ("pipe+res", true, true, false),
        ("pipe+res+route", true, true, true),
    ];
    let mut baseline_makespan = 0u64;
    for (name, pipeline, weight_residency, warm_routing) in combos {
        let o = ServeOptions {
            models: vec![eiq_neutron::zoo::ModelId::MobileNetV2],
            requests: 300,
            mean_gap_cycles: 400_000,
            seed: 13,
            priority_mix: PriorityMix::standard_only(),
            scheduler: SchedulerOptions {
                instances: 2,
                pipeline,
                weight_residency,
                warm_routing,
                ..SchedulerOptions::default()
            },
            ..ServeOptions::default()
        };
        let r = serve_with_cache(&cfg, &o, &mut cache);
        if name == "baseline" {
            baseline_makespan = r.makespan_cycles;
        } else if !warm_routing {
            // Warm routing trades placement for predicted finish and has
            // no monotonicity guarantee; the other combos do.
            assert!(
                r.makespan_cycles <= baseline_makespan,
                "{name} makespan {} exceeds baseline {}",
                r.makespan_cycles,
                baseline_makespan
            );
        }
        assert!(
            r.utilization() <= 1.0 + 1e-12,
            "{name} utilization {} above 1",
            r.utilization()
        );
        println!(
            "{:>14}  {:>14} {:>10.1} {:>10.3} {:>11} {:>8.1}% {:>6}",
            name,
            r.makespan_cycles,
            r.goodput_inf_s,
            r.p99_ms,
            r.overlap_cycles,
            r.residency_hit_rate() * 100.0,
            r.warm_dispatches
        );
        extra_json.push(format!(
            "{{\"name\":\"pipeline_residency_{}\",\"pipeline\":{},\"residency\":{},\
             \"warm_routing\":{},\"makespan_cycles\":{},\"goodput_inf_s\":{},\
             \"overlap_cycles\":{},\"residency_hits\":{},\"residency_misses\":{},\
             \"warm_dispatches\":{}}}",
            name,
            pipeline,
            weight_residency,
            warm_routing,
            r.makespan_cycles,
            r.goodput_inf_s,
            r.overlap_cycles,
            r.residency_hits,
            r.residency_misses,
            r.warm_dispatches
        ));
    }

    let report = serve_with_cache(&cfg, &ServeOptions::default(), &mut cache);
    println!("\n{}", report.summary());

    if let Some(path) = json_path {
        let mut rows: Vec<String> = results
            .iter()
            .map(|m| {
                format!(
                    "{{\"name\":{:?},\"median_us\":{:.1},\"mean_us\":{:.1},\"stddev_us\":{:.1}}}",
                    m.name,
                    m.median().as_secs_f64() * 1e6,
                    m.mean().as_secs_f64() * 1e6,
                    m.stddev_us()
                )
            })
            .collect();
        rows.extend(extra_json);
        let json = format!("[\n  {}\n]\n", rows.join(",\n  "));
        std::fs::write(&path, json).expect("write bench JSON");
        eprintln!("wrote {path}");
    }
}
