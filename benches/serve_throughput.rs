//! Bench: multi-tenant serving throughput — compile-cache cold vs warm,
//! and scaling across virtual NPU instance counts (the utilization story
//! of the paper, lifted to the serving layer).

use eiq_neutron::arch::NeutronConfig;
use eiq_neutron::serve::{serve, serve_with_cache, CompileCache, ServeOptions};
use eiq_neutron::util::bench::Bencher;

fn main() {
    let cfg = NeutronConfig::flagship_2tops();
    let opts = ServeOptions::default();
    let b = Bencher::quick();

    // Cold cache: every sample pays the full CP compile for each model.
    b.bench("serve 200 req / 3 models, cold cache", || {
        serve(&cfg, &opts).throughput_inf_s
    });

    // Warm cache: compiles amortized away; scaling is pure scheduling.
    let mut cache = CompileCache::for_serving(cfg.clone());
    for &model in &opts.models {
        cache.get(model);
    }
    for instances in [1usize, 2, 4, 8] {
        let o = ServeOptions { instances, ..opts.clone() };
        b.bench(&format!("serve 200 req warm cache, {instances} instance(s)"), || {
            serve_with_cache(&cfg, &o, &mut cache).throughput_inf_s
        });
    }

    let report = serve_with_cache(&cfg, &ServeOptions::default(), &mut cache);
    println!("\n{}", report.summary());
}
