//! Bench: serving throughput under load — compile-cache cold vs warm,
//! instance scaling, and the overload sweep (offered load vs goodput and
//! tail latency with shedding and batching). The sweep is the acceptance
//! evidence for the overload-aware scheduler: goodput saturates (instead
//! of collapsing) past the knee with shedding on, and batching buys extra
//! goodput at the same offered load.

use eiq_neutron::arch::NeutronConfig;
use eiq_neutron::serve::{
    serve, serve_with_cache, AdmissionPolicy, CompileCache, SchedulerOptions, ServeOptions,
};
use eiq_neutron::util::bench::Bencher;

fn main() {
    let cfg = NeutronConfig::flagship_2tops();
    let opts = ServeOptions::default();
    let b = Bencher::quick();

    // Cold cache: every sample pays the full CP compile for each model.
    b.bench("serve 200 req / 3 models, cold cache", || {
        serve(&cfg, &opts).goodput_inf_s
    });

    // Warm cache: compiles amortized away; scaling is pure scheduling.
    let mut cache = CompileCache::for_serving(cfg.clone());
    for &model in &opts.models {
        cache.get(model);
    }
    for instances in [1usize, 2, 4, 8] {
        let o = ServeOptions {
            scheduler: SchedulerOptions { instances, ..opts.scheduler.clone() },
            ..opts.clone()
        };
        b.bench(&format!("serve 200 req warm cache, {instances} instance(s)"), || {
            serve_with_cache(&cfg, &o, &mut cache).goodput_inf_s
        });
    }

    // Overload sweep: a fixed 2-instance fleet while the offered load ramps
    // from under the service knee to ~8× past it (the mean gap halves every
    // row). Three scheduler shapes per load point:
    //   unbounded   — the PR-1 queue: nothing sheds, queueing delay (and
    //                 p99) grows with the backlog;
    //   shed        — queue capacity 16, reject-newest: goodput saturates
    //                 at the service rate and p99 stays bounded;
    //   shed+batch  — same, plus same-model batching (max_batch 8):
    //                 followers skip parameter fetches, so the saturated
    //                 goodput rises above the unbatched ceiling.
    println!("\noverload sweep: 400 requests, 2 instances, 3 models, seed 7");
    println!(
        "{:>9}  {:<11} {:>10} {:>10} {:>7} {:>10} {:>10} {:>8}",
        "gap cyc", "scheduler", "offered/s", "goodput/s", "shed%", "p50 ms", "p99 ms", "batched"
    );
    for gap in [1_200_000u64, 600_000, 300_000, 150_000, 75_000] {
        let shapes: [(&str, SchedulerOptions); 3] = [
            ("unbounded", SchedulerOptions { instances: 2, ..SchedulerOptions::default() }),
            (
                "shed",
                SchedulerOptions {
                    instances: 2,
                    queue_capacity: Some(16),
                    policy: AdmissionPolicy::RejectNewest,
                    ..SchedulerOptions::default()
                },
            ),
            (
                "shed+batch",
                SchedulerOptions {
                    instances: 2,
                    queue_capacity: Some(16),
                    policy: AdmissionPolicy::RejectNewest,
                    max_batch: 8,
                    ..SchedulerOptions::default()
                },
            ),
        ];
        for (name, scheduler) in shapes {
            let o = ServeOptions {
                requests: 400,
                mean_gap_cycles: gap,
                scheduler,
                ..ServeOptions::default()
            };
            let r = serve_with_cache(&cfg, &o, &mut cache);
            println!(
                "{:>9}  {:<11} {:>10.1} {:>10.1} {:>6.1}% {:>10.3} {:>10.3} {:>8}",
                gap,
                name,
                r.offered_load_inf_s,
                r.goodput_inf_s,
                r.shed_rate() * 100.0,
                r.p50_ms,
                r.p99_ms,
                r.batched_requests
            );
        }
    }

    let report = serve_with_cache(&cfg, &ServeOptions::default(), &mut cache);
    println!("\n{}", report.summary());
}
