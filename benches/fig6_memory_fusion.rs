//! Bench: regenerate Fig. 6 — memory usage over time for the first five
//! layers of MobileNetV2, with and without the fusion+tiling optimization.

fn main() {
    eiq_neutron::report::fig6();
}
