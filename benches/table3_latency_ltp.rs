//! Bench: regenerate Table III — latency + LTP for all 12 Table-IV models
//! across Ours / eNPU-A / eNPU-B / iNPU, plus compile+simulate wall times.

use eiq_neutron::arch::NeutronConfig;
use eiq_neutron::compiler::{compile, CompileOptions};
use eiq_neutron::sim::{simulate, SimOptions};
use eiq_neutron::util::bench::Bencher;
use eiq_neutron::zoo::ModelId;

fn main() {
    eiq_neutron::report::table3();

    println!("\n-- harness timings (compile + simulate per model) --");
    let b = Bencher::quick();
    let cfg = NeutronConfig::flagship_2tops();
    for id in [ModelId::MobileNetV2, ModelId::ResNet50V1, ModelId::YoloV8nDet] {
        let g = id.build();
        b.bench(&format!("compile+sim {}", id.display_name()), || {
            let c = compile(&g, &cfg, &CompileOptions::default_partitioned());
            simulate(&c, &cfg, &SimOptions::default()).total_cycles
        });
    }
}
