//! Bench: CP-solver hot paths (the compiler's dominant cost — §Perf).
//!
//! Microbenches the substrate on problem shapes the mid-end produces:
//! knapsack-style selection (tiling), window placement (scheduling), plus
//! one real full-mid-end compile.

use eiq_neutron::arch::NeutronConfig;
use eiq_neutron::compiler::{compile, CompileOptions};
use eiq_neutron::cp::{solve, CpModel, LinExpr, SearchConfig};
use eiq_neutron::util::bench::Bencher;
use eiq_neutron::zoo::ModelId;

fn knapsack(n: usize) -> CpModel {
    let mut m = CpModel::new();
    let vars: Vec<_> = (0..n).map(|i| m.bool_var(format!("x{i}"))).collect();
    let w = LinExpr::weighted_sum(
        vars.iter().enumerate().map(|(i, &v)| ((i as i64 * 7 % 13) + 1, v)),
    );
    m.add_le(w, (n as i64 * 13) / 5);
    m.minimize(LinExpr::weighted_sum(
        vars.iter().enumerate().map(|(i, &v)| (-((i as i64 * 11 % 17) + 1), v)),
    ));
    m
}

/// Scheduling-window shape: transfers choose one of 3 ticks; tick latency
/// variables bound the per-tick load; objective Σ L_t + δ·N_DM.
fn window_placement(transfers: usize, ticks: usize) -> CpModel {
    let mut m = CpModel::new();
    let mut obj = LinExpr::new();
    let mut per_tick_terms: Vec<Vec<(i64, eiq_neutron::cp::Var)>> = vec![Vec::new(); ticks];
    for t in 0..transfers {
        let lo = t % ticks;
        let slots: Vec<_> = (0..3).map(|d| m.bool_var(format!("x{t}_{d}"))).collect();
        m.add_exactly_one(slots.clone());
        for (d, &v) in slots.iter().enumerate() {
            let tick = (lo + d) % ticks;
            per_tick_terms[tick].push((((t as i64 * 97) % 900) + 100, v));
            obj.push(8, v);
        }
    }
    for (i, terms) in per_tick_terms.into_iter().enumerate() {
        let l = m.int_var(200, 100_000, format!("L{i}"));
        let mut con = LinExpr::var(l);
        for (c, v) in terms {
            con.push(-c, v);
        }
        m.add_ge(con, 0);
        obj.push(1, l);
    }
    m.minimize(obj);
    m
}

fn main() {
    let b = Bencher::default();
    for n in [16usize, 32, 64] {
        let m = knapsack(n);
        b.bench(&format!("cp knapsack n={n}"), || {
            solve(&m, SearchConfig::default()).objective
        });
    }
    for (t, k) in [(12usize, 12usize), (24, 12), (48, 16)] {
        let m = window_placement(t, k);
        b.bench(&format!("cp window t={t} ticks={k}"), || {
            solve(&m, SearchConfig { time_limit_ms: Some(2000), ..Default::default() }).objective
        });
    }

    let cfg = NeutronConfig::flagship_2tops();
    let g = ModelId::MobileNetV2.build();
    b.bench("compile mobilenet-v2 (full mid-end)", || {
        compile(&g, &cfg, &CompileOptions::default_partitioned())
            .schedule
            .solve_ms
    });
}
