//! Bench: CP-solver hot paths (the compiler's dominant cost — §Perf).
//!
//! Microbenches the substrate on problem shapes the mid-end produces:
//! knapsack-style selection (tiling), window placement (scheduling), one
//! real full-mid-end compile, and a **warm-vs-cold sweep**: the same
//! mid-end recompiled with the anytime search seeded by a prior artifact
//! at 100% / 50% / 25% of the deterministic node budgets. The sweep
//! asserts the tentpole acceptance bound — a warm-started compile reaches
//! the cold objective (estimated inference latency) with ≤50% of the
//! node budget.
//!
//! `--json PATH` additionally writes the measurements as a JSON array
//! (used by ci.sh to emit `BENCH_solver_hotpath.json`).

use std::sync::Arc;

use eiq_neutron::arch::NeutronConfig;
use eiq_neutron::compiler::{compile, CompileOptions};
use eiq_neutron::cp::{solve, CpModel, LinExpr, SearchConfig};
use eiq_neutron::serve::deterministic_compile_options;
use eiq_neutron::util::bench::{Bencher, Measurement};
use eiq_neutron::zoo::ModelId;

fn knapsack(n: usize) -> CpModel {
    let mut m = CpModel::new();
    let vars: Vec<_> = (0..n).map(|i| m.bool_var(format!("x{i}"))).collect();
    let w = LinExpr::weighted_sum(
        vars.iter().enumerate().map(|(i, &v)| ((i as i64 * 7 % 13) + 1, v)),
    );
    m.add_le(w, (n as i64 * 13) / 5);
    m.minimize(LinExpr::weighted_sum(
        vars.iter().enumerate().map(|(i, &v)| (-((i as i64 * 11 % 17) + 1), v)),
    ));
    m
}

/// Scheduling-window shape: transfers choose one of 3 ticks; tick latency
/// variables bound the per-tick load; objective Σ L_t + δ·N_DM.
fn window_placement(transfers: usize, ticks: usize) -> CpModel {
    let mut m = CpModel::new();
    let mut obj = LinExpr::new();
    let mut per_tick_terms: Vec<Vec<(i64, eiq_neutron::cp::Var)>> = vec![Vec::new(); ticks];
    for t in 0..transfers {
        let lo = t % ticks;
        let slots: Vec<_> = (0..3).map(|d| m.bool_var(format!("x{t}_{d}"))).collect();
        m.add_exactly_one(slots.clone());
        for (d, &v) in slots.iter().enumerate() {
            let tick = (lo + d) % ticks;
            per_tick_terms[tick].push((((t as i64 * 97) % 900) + 100, v));
            obj.push(8, v);
        }
    }
    for (i, terms) in per_tick_terms.into_iter().enumerate() {
        let l = m.int_var(200, 100_000, format!("L{i}"));
        let mut con = LinExpr::var(l);
        for (c, v) in terms {
            con.push(-c, v);
        }
        m.add_ge(con, 0);
        obj.push(1, l);
    }
    m.minimize(obj);
    m
}

/// The deterministic serving budgets with every node limit scaled by
/// `percent` — the anytime-budget knob the warm sweep turns.
fn budgets_at(percent: u64) -> CompileOptions {
    let mut opts = deterministic_compile_options();
    let scale = |cfg: &mut SearchConfig| {
        cfg.node_limit = cfg.node_limit.map(|n| (n * percent / 100).max(1));
    };
    scale(&mut opts.tiling.solver);
    scale(&mut opts.scheduling.solver);
    scale(&mut opts.allocation_solver);
    opts
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let json_path = args
        .iter()
        .position(|a| a == "--json")
        .and_then(|i| args.get(i + 1))
        .cloned();

    let b = Bencher::default();
    let mut results: Vec<Measurement> = Vec::new();
    let mut extra_json: Vec<String> = Vec::new();

    for n in [16usize, 32, 64] {
        let m = knapsack(n);
        results.push(b.bench(&format!("cp knapsack n={n}"), || {
            solve(&m, SearchConfig::default()).objective
        }));
    }
    for (t, k) in [(12usize, 12usize), (24, 12), (48, 16)] {
        let m = window_placement(t, k);
        results.push(b.bench(&format!("cp window t={t} ticks={k}"), || {
            solve(&m, SearchConfig { time_limit_ms: Some(2000), ..Default::default() }).objective
        }));
    }

    // CP-level warm restart: seeding the window CP with its own optimum
    // turns the search into a pure optimality proof — fewer nodes, same
    // objective.
    {
        let m = window_placement(24, 12);
        let cold = solve(&m, SearchConfig { time_limit_ms: Some(2000), ..Default::default() });
        let seed = cold.assignment.clone().expect("window CP is feasible");
        let warm = solve(
            &m,
            SearchConfig {
                time_limit_ms: Some(2000),
                hint: Some(seed),
                ..Default::default()
            },
        );
        let (cold_obj, warm_obj) =
            (cold.objective.expect("cold solution"), warm.objective.expect("warm solution"));
        assert!(
            warm_obj <= cold_obj,
            "warm CP ended worse than its own seed: {warm_obj} vs {cold_obj}"
        );
        println!(
            "cp window warm restart: {} → {} nodes to re-prove the optimum",
            cold.nodes, warm.nodes
        );
        extra_json.push(format!(
            "{{\"name\":\"cp_window_warm_restart\",\"cold_nodes\":{},\"warm_nodes\":{}}}",
            cold.nodes, warm.nodes
        ));
    }

    let cfg = NeutronConfig::flagship_2tops();
    let g = ModelId::MobileNetV2.build();
    results.push(b.bench("compile mobilenet-v2 (full mid-end)", || {
        compile(&g, &cfg, &CompileOptions::default_partitioned())
            .schedule
            .solve_ms
    }));

    // Warm-vs-cold sweep: recompile seeded with the cold artifact at
    // shrinking node budgets. Acceptance bound: at ≤50% budget the warm
    // compile still reaches the cold objective.
    let sweep_model = ModelId::MobileNetV3Min;
    let sweep_graph = sweep_model.build();
    let cold = Arc::new(compile(&sweep_graph, &cfg, &budgets_at(100)));
    println!(
        "warm sweep {}: cold inference {:.4} ms ({} ms compile)",
        sweep_model.slug(),
        cold.inference_ms,
        cold.compile_ms
    );
    for percent in [100u64, 50, 25] {
        let opts = CompileOptions {
            warm_start: Some(Arc::clone(&cold)),
            ..budgets_at(percent)
        };
        let name = format!("compile {} warm @{percent}% budget", sweep_model.slug());
        results.push(b.bench(&name, || compile(&sweep_graph, &cfg, &opts).inference_ms));
        let warm = compile(&sweep_graph, &cfg, &opts);
        println!(
            "warm sweep {}: @{percent}% budget → {:.4} ms inference",
            sweep_model.slug(),
            warm.inference_ms
        );
        if percent >= 50 {
            assert!(
                warm.inference_ms <= cold.inference_ms * (1.0 + 1e-9),
                "warm @{percent}% budget worse than cold: {} vs {}",
                warm.inference_ms,
                cold.inference_ms
            );
        }
        extra_json.push(format!(
            "{{\"name\":\"warm_sweep_{}_{percent}pct\",\"inference_ms\":{},\"cold_inference_ms\":{}}}",
            sweep_model.slug(),
            warm.inference_ms,
            cold.inference_ms
        ));
    }

    if let Some(path) = json_path {
        let mut rows: Vec<String> = results
            .iter()
            .map(|m| {
                format!(
                    "{{\"name\":{:?},\"median_us\":{:.1},\"mean_us\":{:.1},\"stddev_us\":{:.1}}}",
                    m.name,
                    m.median().as_secs_f64() * 1e6,
                    m.mean().as_secs_f64() * 1e6,
                    m.stddev_us()
                )
            })
            .collect();
        rows.extend(extra_json);
        let json = format!("[\n  {}\n]\n", rows.join(",\n  "));
        std::fs::write(&path, json).expect("write bench JSON");
        eprintln!("wrote {path}");
    }
}
