//! Bench: CP-solver hot paths (the compiler's dominant cost — §Perf).
//!
//! Microbenches the substrate on problem shapes the mid-end produces:
//! knapsack-style selection (tiling), window placement (scheduling), one
//! real full-mid-end compile, and a **warm-vs-cold sweep**: the same
//! mid-end recompiled with the anytime search seeded by a prior artifact
//! at 100% / 50% / 25% of the deterministic node budgets. The sweep
//! asserts the tentpole acceptance bound — a warm-started compile reaches
//! the cold objective (estimated inference latency) with ≤50% of the
//! node budget.
//!
//! `--json PATH` additionally writes the measurements as a JSON array
//! (used by ci.sh to emit `BENCH_solver_hotpath.json`).

use std::sync::Arc;

use eiq_neutron::arch::NeutronConfig;
use eiq_neutron::compiler::{
    compile, compile_with_stats, schedule_with_stats, select_formats_with, tile_graph_with_stats,
    CompileOptions, CostModel,
};
use eiq_neutron::cp::{solve, CpModel, EngineKind, LinExpr, SearchConfig, SolveStats};
use eiq_neutron::serve::deterministic_compile_options;
use eiq_neutron::util::bench::{Bencher, Measurement};
use eiq_neutron::zoo::ModelId;

fn knapsack(n: usize) -> CpModel {
    let mut m = CpModel::new();
    let vars: Vec<_> = (0..n).map(|i| m.bool_var(format!("x{i}"))).collect();
    let w = LinExpr::weighted_sum(
        vars.iter().enumerate().map(|(i, &v)| ((i as i64 * 7 % 13) + 1, v)),
    );
    m.add_le(w, (n as i64 * 13) / 5);
    m.minimize(LinExpr::weighted_sum(
        vars.iter().enumerate().map(|(i, &v)| (-((i as i64 * 11 % 17) + 1), v)),
    ));
    m
}

/// Scheduling-window shape: transfers choose one of 3 ticks; tick latency
/// variables bound the per-tick load; objective Σ L_t + δ·N_DM.
fn window_placement(transfers: usize, ticks: usize) -> CpModel {
    let mut m = CpModel::new();
    let mut obj = LinExpr::new();
    let mut per_tick_terms: Vec<Vec<(i64, eiq_neutron::cp::Var)>> = vec![Vec::new(); ticks];
    for t in 0..transfers {
        let lo = t % ticks;
        let slots: Vec<_> = (0..3).map(|d| m.bool_var(format!("x{t}_{d}"))).collect();
        m.add_exactly_one(slots.clone());
        for (d, &v) in slots.iter().enumerate() {
            let tick = (lo + d) % ticks;
            per_tick_terms[tick].push((((t as i64 * 97) % 900) + 100, v));
            obj.push(8, v);
        }
    }
    for (i, terms) in per_tick_terms.into_iter().enumerate() {
        let l = m.int_var(200, 100_000, format!("L{i}"));
        let mut con = LinExpr::var(l);
        for (c, v) in terms {
            con.push(-c, v);
        }
        m.add_ge(con, 0);
        obj.push(1, l);
    }
    m.minimize(obj);
    m
}

/// The deterministic serving budgets with every node limit scaled by
/// `percent` — the anytime-budget knob the warm sweep turns.
fn budgets_at(percent: u64) -> CompileOptions {
    let mut opts = deterministic_compile_options();
    let scale = |cfg: &mut SearchConfig| {
        cfg.node_limit = cfg.node_limit.map(|n| (n * percent / 100).max(1));
    };
    scale(&mut opts.tiling.solver);
    scale(&mut opts.scheduling.solver);
    scale(&mut opts.allocation_solver);
    opts
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let json_path = args
        .iter()
        .position(|a| a == "--json")
        .and_then(|i| args.get(i + 1))
        .cloned();

    let b = Bencher::default();
    let mut results: Vec<Measurement> = Vec::new();
    let mut extra_json: Vec<String> = Vec::new();

    for n in [16usize, 32, 64] {
        let m = knapsack(n);
        results.push(b.bench(&format!("cp knapsack n={n}"), || {
            solve(&m, SearchConfig::default()).objective
        }));
    }
    for (t, k) in [(12usize, 12usize), (24, 12), (48, 16)] {
        let m = window_placement(t, k);
        results.push(b.bench(&format!("cp window t={t} ticks={k}"), || {
            solve(&m, SearchConfig { time_limit_ms: Some(2000), ..Default::default() }).objective
        }));
    }

    // CP-level warm restart: seeding the window CP with its own optimum
    // turns the search into a pure optimality proof — fewer nodes, same
    // objective.
    {
        let m = window_placement(24, 12);
        let cold = solve(&m, SearchConfig { time_limit_ms: Some(2000), ..Default::default() });
        let seed = cold.assignment.clone().expect("window CP is feasible");
        let warm = solve(
            &m,
            SearchConfig {
                time_limit_ms: Some(2000),
                hint: Some(seed),
                ..Default::default()
            },
        );
        let (cold_obj, warm_obj) =
            (cold.objective.expect("cold solution"), warm.objective.expect("warm solution"));
        assert!(
            warm_obj <= cold_obj,
            "warm CP ended worse than its own seed: {warm_obj} vs {cold_obj}"
        );
        println!(
            "cp window warm restart: {} → {} nodes to re-prove the optimum",
            cold.nodes, warm.nodes
        );
        extra_json.push(format!(
            "{{\"name\":\"cp_window_warm_restart\",\"cold_nodes\":{},\"warm_nodes\":{}}}",
            cold.nodes, warm.nodes
        ));
    }

    let cfg = NeutronConfig::flagship_2tops();
    let g = ModelId::MobileNetV2.build();
    results.push(b.bench("compile mobilenet-v2 (full mid-end)", || {
        compile(&g, &cfg, &CompileOptions::default_partitioned())
            .schedule
            .solve_ms
    }));

    // Warm-vs-cold sweep: recompile seeded with the cold artifact at
    // shrinking node budgets. Acceptance bound: at ≤50% budget the warm
    // compile still reaches the cold objective.
    let sweep_model = ModelId::MobileNetV3Min;
    let sweep_graph = sweep_model.build();
    let cold = Arc::new(compile(&sweep_graph, &cfg, &budgets_at(100)));
    println!(
        "warm sweep {}: cold inference {:.4} ms ({} ms compile)",
        sweep_model.slug(),
        cold.inference_ms,
        cold.compile_ms
    );
    for percent in [100u64, 50, 25] {
        let opts = CompileOptions {
            warm_start: Some(Arc::clone(&cold)),
            ..budgets_at(percent)
        };
        let name = format!("compile {} warm @{percent}% budget", sweep_model.slug());
        results.push(b.bench(&name, || compile(&sweep_graph, &cfg, &opts).inference_ms));
        let warm = compile(&sweep_graph, &cfg, &opts);
        println!(
            "warm sweep {}: @{percent}% budget → {:.4} ms inference",
            sweep_model.slug(),
            warm.inference_ms
        );
        if percent >= 50 {
            assert!(
                warm.inference_ms <= cold.inference_ms * (1.0 + 1e-9),
                "warm @{percent}% budget worse than cold: {} vs {}",
                warm.inference_ms,
                cold.inference_ms
            );
        }
        extra_json.push(format!(
            "{{\"name\":\"warm_sweep_{}_{percent}pct\",\"inference_ms\":{},\"cold_inference_ms\":{}}}",
            sweep_model.slug(),
            warm.inference_ms,
            cold.inference_ms
        ));
    }

    // Old-vs-new engine comparison: compile every zoo model once per engine
    // at the deterministic serving budgets (node-limited, no wall clock) and
    // report nodes/sec and propagations/node. The equivalence contract
    // (rust/tests/cp_differential.rs, docs/solver.md) makes the two trees
    // identical, so the acceptance bound "incremental explores no more
    // nodes than the reference at equal budgets" must hold with equality —
    // any violation means the engines diverged.
    let engine_opts = |engine: EngineKind| -> CompileOptions {
        let mut o = deterministic_compile_options();
        o.tiling.solver.engine = engine;
        o.scheduling.solver.engine = engine;
        o.allocation_solver.engine = engine;
        o
    };
    let nodes_per_sec = |st: &SolveStats, secs: f64| {
        if secs > 0.0 {
            st.nodes as f64 / secs
        } else {
            0.0
        }
    };
    let props_per_node = |st: &SolveStats| {
        if st.nodes > 0 {
            st.propagations as f64 / st.nodes as f64
        } else {
            0.0
        }
    };
    println!("engine comparison (deterministic serving budgets, full zoo):");
    for model in ModelId::all() {
        let g = model.build();
        let t0 = std::time::Instant::now();
        let (_, ref_stats) = compile_with_stats(&g, &cfg, &engine_opts(EngineKind::Reference));
        let ref_secs = t0.elapsed().as_secs_f64();
        let t1 = std::time::Instant::now();
        let (_, inc_stats) = compile_with_stats(&g, &cfg, &engine_opts(EngineKind::Incremental));
        let inc_secs = t1.elapsed().as_secs_f64();
        assert!(
            inc_stats.nodes <= ref_stats.nodes,
            "{}: incremental explored more nodes than the reference ({} vs {})",
            model.slug(),
            inc_stats.nodes,
            ref_stats.nodes
        );
        println!(
            "  {:<22} {:>8} nodes | inc {:>9.0} n/s {:>6.1} p/n | ref {:>9.0} n/s {:>6.1} p/n",
            model.slug(),
            inc_stats.nodes,
            nodes_per_sec(&inc_stats, inc_secs),
            props_per_node(&inc_stats),
            nodes_per_sec(&ref_stats, ref_secs),
            props_per_node(&ref_stats)
        );
        extra_json.push(format!(
            "{{\"name\":\"engine_cmp_{}\",\"inc_nodes\":{},\"ref_nodes\":{},\
             \"inc_nodes_per_sec\":{:.1},\"ref_nodes_per_sec\":{:.1},\
             \"inc_props_per_node\":{:.3},\"ref_props_per_node\":{:.3}}}",
            model.slug(),
            inc_stats.nodes,
            ref_stats.nodes,
            nodes_per_sec(&inc_stats, inc_secs),
            nodes_per_sec(&ref_stats, ref_secs),
            props_per_node(&inc_stats),
            props_per_node(&ref_stats)
        ));
    }

    // Scheduling-CP head-to-head on the heaviest zoo model: same tiled
    // program, one timed scheduling pass per engine. DAE window placement
    // is the hot path the cached activities target, so the nodes/sec ratio
    // here is the headline number for the incremental rewrite.
    {
        let heaviest = ModelId::all()
            .into_iter()
            .max_by_key(|m| m.build().total_macs())
            .expect("zoo is non-empty");
        let g = heaviest.build();
        let cost = CostModel::uncalibrated(&cfg);
        let formats = select_formats_with(&g, &cost);
        let det = deterministic_compile_options();
        let (prog, _) = tile_graph_with_stats(&g, &formats, &cost, &det.tiling);
        let timed = |engine: EngineKind| {
            let mut opts = det.scheduling.clone();
            opts.solver.engine = engine;
            let t0 = std::time::Instant::now();
            let (_, stats) = schedule_with_stats(&prog, &cost, &opts);
            (t0.elapsed().as_secs_f64(), stats)
        };
        let (ref_secs, ref_stats) = timed(EngineKind::Reference);
        let (inc_secs, inc_stats) = timed(EngineKind::Incremental);
        assert!(
            inc_stats.nodes <= ref_stats.nodes,
            "scheduling CP: incremental explored more nodes ({} vs {})",
            inc_stats.nodes,
            ref_stats.nodes
        );
        let speedup = if inc_secs > 0.0 { ref_secs / inc_secs } else { 0.0 };
        println!(
            "scheduling CP on {} ({} nodes): inc {:.0} n/s vs ref {:.0} n/s ({:.2}x)",
            heaviest.slug(),
            inc_stats.nodes,
            nodes_per_sec(&inc_stats, inc_secs),
            nodes_per_sec(&ref_stats, ref_secs),
            speedup
        );
        extra_json.push(format!(
            "{{\"name\":\"engine_cmp_scheduling_{}\",\"inc_nodes\":{},\"ref_nodes\":{},\
             \"inc_nodes_per_sec\":{:.1},\"ref_nodes_per_sec\":{:.1},\"speedup\":{:.3}}}",
            heaviest.slug(),
            inc_stats.nodes,
            ref_stats.nodes,
            nodes_per_sec(&inc_stats, inc_secs),
            nodes_per_sec(&ref_stats, ref_secs),
            speedup
        ));
    }

    if let Some(path) = json_path {
        let mut rows: Vec<String> = results
            .iter()
            .map(|m| {
                format!(
                    "{{\"name\":{:?},\"median_us\":{:.1},\"mean_us\":{:.1},\"stddev_us\":{:.1}}}",
                    m.name,
                    m.median().as_secs_f64() * 1e6,
                    m.mean().as_secs_f64() * 1e6,
                    m.stddev_us()
                )
            })
            .collect();
        rows.extend(extra_json);
        let json = format!("[\n  {}\n]\n", rows.join(",\n  "));
        std::fs::write(&path, json).expect("write bench JSON");
        eprintln!("wrote {path}");
    }
}
