"""L1 kernel correctness: neutron_mm vs the pure-jnp oracle.

Hypothesis sweeps shapes/values; fixed cases pin the block-boundary and
requant edge behaviour. Bit-exactness (array_equal, not allclose) is the
contract — the rust runtime replays the same integer arithmetic.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.neutron_mm import (
    BK,
    BM,
    BN,
    matmul_i8,
    mxu_utilization_estimate,
    vmem_bytes_per_step,
)


def run_case(m, k, n, seed, relu=False):
    rng = np.random.default_rng(seed)
    lhs, rhs, bias, mult, shift = ref.random_quant_case(rng, m, k, n)
    got = np.asarray(matmul_i8(lhs, rhs, bias, multiplier=mult, shift=shift, relu=relu))
    want = np.asarray(ref.matmul_i8_ref(lhs, rhs, bias, mult, shift, relu=relu))
    np.testing.assert_array_equal(got, want)


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 160),
    k=st.integers(1, 300),
    n=st.integers(1, 160),
    seed=st.integers(0, 2**31),
    relu=st.booleans(),
)
def test_matmul_matches_ref_hypothesis(m, k, n, seed, relu):
    run_case(m, k, n, seed, relu)


@pytest.mark.parametrize(
    "m,k,n",
    [
        (BM, BK, BN),              # exactly one block
        (BM + 1, BK + 1, BN + 1),  # one past the block boundary
        (BM - 1, BK - 1, BN - 1),  # one short
        (1, 1, 1),                 # degenerate
        (2 * BM, 3 * BK, 2 * BN),  # multi-block grid
        (7, 513, 9),               # deep contraction, thin output
    ],
)
def test_matmul_block_boundaries(m, k, n):
    run_case(m, k, n, seed=42)
    run_case(m, k, n, seed=43, relu=True)


def test_relu_clamps_negatives():
    rng = np.random.default_rng(5)
    lhs, rhs, bias, mult, shift = ref.random_quant_case(rng, 16, 32, 16)
    out = np.asarray(matmul_i8(lhs, rhs, bias, multiplier=mult, shift=shift, relu=True))
    assert out.min() >= 0


def test_saturation_at_extremes():
    # All-max inputs with a large multiplier must saturate, not wrap.
    m, k, n = 8, 64, 8
    lhs = np.full((m, k), 127, dtype=np.int8)
    rhs = np.full((k, n), 127, dtype=np.int8)
    bias = np.zeros(n, dtype=np.int32)
    mult, shift = ref.requant_from_real(0.9)
    got = np.asarray(matmul_i8(lhs, rhs, bias, multiplier=mult, shift=shift))
    assert (got == 127).all()
    got_neg = np.asarray(
        matmul_i8(-lhs, rhs, bias, multiplier=mult, shift=shift)
    )
    assert (got_neg == -128).all()


@settings(max_examples=15, deadline=None)
@given(real=st.floats(1e-4, 4.0))
def test_requant_decomposition_roundtrip(real):
    mult, shift = ref.requant_from_real(real)
    assert (1 << 30) <= mult < (1 << 31)
    back = mult / (1 << 31) / (2.0**shift)
    assert abs(back - real) / real < 1e-6


@settings(max_examples=20, deadline=None)
@given(acc=st.integers(-(2**28), 2**28), real=st.floats(1e-4, 0.5))
def test_requant_apply_tracks_float(acc, real):
    import jax.numpy as jnp

    mult, shift = ref.requant_from_real(real)
    got = int(ref.requant_apply(jnp.int32(acc), mult, shift))
    want = round(acc * real)
    assert abs(got - want) <= 1


def test_vmem_footprint_fits_tpu_budget():
    # The DESIGN.md §8 claim: one grid step's working set ≪ 16 MiB VMEM.
    assert vmem_bytes_per_step() < 256 * 1024


def test_mxu_utilization_estimates():
    assert mxu_utilization_estimate(BM, BK, BN) == 1.0
    # Ragged shapes pay padding.
    assert mxu_utilization_estimate(BM + 1, BK, BN) < 0.6
    assert 0.0 < mxu_utilization_estimate(3, 5, 7) < 0.01
