"""L2 model correctness: traced forward vs oracle, conv lowering, and the
AOT artifact contract the rust runtime relies on."""

import os

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import aot, model as model_mod
from compile.kernels import ref


def test_quickstart_build_is_deterministic():
    a = model_mod.build_quickstart(seed=7)
    b = model_mod.build_quickstart(seed=7)
    for la, lb in zip(a.layers, b.layers):
        np.testing.assert_array_equal(la.weights, lb.weights)
        assert (la.multiplier, la.shift) == (lb.multiplier, lb.shift)


def test_forward_matches_oracle():
    m = model_mod.build_quickstart(seed=7, input_hw=16)
    rng = np.random.default_rng(0)
    x = rng.integers(-128, 128, size=(16, 16, 3), dtype=np.int8)
    traced = np.asarray(model_mod.forward_fn(m)(jnp.asarray(x))[0])
    oracle = model_mod.reference_forward(m, x)
    np.testing.assert_array_equal(traced, oracle)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 2**31), hw=st.sampled_from([8, 16, 24]))
def test_forward_matches_oracle_across_seeds(seed, hw):
    m = model_mod.build_quickstart(seed=seed % 1000, input_hw=hw)
    rng = np.random.default_rng(seed)
    x = rng.integers(-128, 128, size=(hw, hw, 3), dtype=np.int8)
    traced = np.asarray(model_mod.forward_fn(m)(jnp.asarray(x))[0])
    oracle = model_mod.reference_forward(m, x)
    np.testing.assert_array_equal(traced, oracle)


@settings(max_examples=10, deadline=None)
@given(
    hw=st.sampled_from([6, 9, 12]),
    cin=st.integers(1, 8),
    cout=st.integers(1, 24),
    kernel=st.sampled_from([1, 3, 5]),
    stride=st.sampled_from([1, 2]),
    seed=st.integers(0, 2**31),
)
def test_conv_block_matches_conv_oracle(hw, cin, cout, kernel, stride, seed):
    """The im2col lowering in model.py == direct conv in ref.py."""
    rng = np.random.default_rng(seed)
    x = rng.integers(-128, 128, size=(hw, hw, cin), dtype=np.int8)
    w = rng.integers(-64, 64, size=(cout, kernel, kernel, cin), dtype=np.int8)
    b = rng.integers(-512, 512, size=(cout,), dtype=np.int32)
    mult, shift = ref.requant_from_real(0.01)
    layer = model_mod.ConvLayer("t", cout, kernel, stride, True, w, b, mult, shift)
    got = np.asarray(model_mod.conv_block(jnp.asarray(x), layer))
    want = np.asarray(
        ref.conv2d_i8_ref(jnp.asarray(x), jnp.asarray(w), jnp.asarray(b),
                          mult, shift, stride=stride, relu=True)
    )
    np.testing.assert_array_equal(got, want)


def test_hlo_text_export_shape(tmp_path):
    entries = aot.export_model(str(tmp_path), seed=7, input_hw=16)
    text = (tmp_path / "model.hlo.txt").read_text()
    assert text.startswith("HloModule"), "must be HLO text, not a proto"
    assert "s8[16,16,3]" in text, "input parameter shape baked in"
    assert entries["model.input_shape"] == "16x16x3"
    logits = [int(v) for v in entries["model.expected_logits"].split(",")]
    assert len(logits) == 10


def test_kernel_export_manifest(tmp_path):
    entries = aot.export_kernel(str(tmp_path))
    text = (tmp_path / "kernel_mm.hlo.txt").read_text()
    assert text.startswith("HloModule")
    row0 = [int(v) for v in entries["kernel.expected_row0"].split(",")]
    assert len(row0) == aot.KN
    assert all(-128 <= v <= 127 for v in row0)


@pytest.mark.skipif(
    not os.path.exists(os.path.join(os.path.dirname(__file__), "../../artifacts/manifest.txt")),
    reason="artifacts not built",
)
def test_built_artifacts_consistent():
    """The checked-out artifacts/ dir matches a fresh trace (same seeds)."""
    root = os.path.join(os.path.dirname(__file__), "../../artifacts")
    manifest = {}
    with open(os.path.join(root, "manifest.txt")) as f:
        for line in f:
            k, v = line.strip().split("=", 1)
            manifest[k] = v
    m = model_mod.build_quickstart(seed=7, input_hw=int(manifest["model.input_shape"].split("x")[0]))
    rng = np.random.default_rng(int(manifest["model.input_seed"]))
    shape = tuple(int(s) for s in manifest["model.input_shape"].split("x"))
    x = rng.integers(-128, 128, size=shape, dtype=np.int8)
    got = np.asarray(model_mod.forward_fn(m)(jnp.asarray(x))[0])
    want = [int(v) for v in manifest["model.expected_logits"].split(",")]
    assert got.tolist() == want
