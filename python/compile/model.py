"""L2: the quantized model forward graph in JAX, calling the L1 kernel.

The quickstart model is a small INT8 CNN classifier (32×32×3 input, three
conv blocks + head) expressed exactly the way the paper's compiler lowers
layers (Sec. IV-A): convs run as im2col matmuls on the dot-product array,
the head as a 1×1 conv. The whole forward is one jittable function, so
``aot.py`` lowers it to a single HLO module the rust runtime executes with
no Python on the request path.

Layer weights are generated deterministically (seeded) at build time and
baked into the HLO as constants — the artifact is self-contained, mirroring
a compiled LiteRT binary with its parameter blob.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass, field

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np

from .kernels import ref
from .kernels.neutron_mm import matmul_i8


@dataclass
class ConvLayer:
    """One quantized conv layer's static config + baked weights."""

    name: str
    out_c: int
    kernel: int
    stride: int
    relu: bool
    weights: np.ndarray = field(repr=False, default=None)  # (outC, kh, kw, inC)
    bias: np.ndarray = field(repr=False, default=None)     # (outC,) int32
    multiplier: int = 0
    shift: int = 0


@dataclass
class QuickstartModel:
    """Static description of the quickstart CNN."""

    input_hw: int
    input_c: int
    layers: list[ConvLayer]
    num_classes: int

    @property
    def name(self) -> str:
        return f"quickstart_cnn_{self.input_hw}"


def build_quickstart(seed: int = 7, input_hw: int = 32) -> QuickstartModel:
    """Deterministically materialize the quickstart model."""
    rng = np.random.default_rng(seed)
    specs = [
        ("conv1", 16, 3, 2, True),
        ("conv2", 32, 3, 2, True),
        ("conv3", 64, 3, 2, True),
        ("head", 10, 1, 1, False),
    ]
    layers = []
    in_c = 3
    for name, out_c, k, s, relu in specs:
        w = rng.integers(-64, 64, size=(out_c, k, k, in_c), dtype=np.int8)
        b = rng.integers(-(1 << 10), 1 << 10, size=(out_c,), dtype=np.int32)
        # Scale ≈ 1/(rms accumulator) so activations use the int8 range
        # without saturating (rms ≈ sqrt(K)·σ_w·σ_x for random operands).
        k_contraction = k * k * in_c
        target = 1.0 / (np.sqrt(k_contraction) * 37.0 * 74.0 / 48.0)
        mult, shift = ref.requant_from_real(float(target * rng.uniform(0.7, 1.3)))
        layers.append(ConvLayer(name, out_c, k, s, relu, w, b, mult, shift))
        in_c = out_c
    return QuickstartModel(input_hw=input_hw, input_c=3, layers=layers, num_classes=10)


def _im2col(x, kernel: int, stride: int):
    """SAME-padded im2col: (H,W,C) → (oh*ow, k*k*C), int8.

    Static shapes only — this traces into the HLO artifact.
    """
    h, w, c = x.shape
    oh, ow = -(-h // stride), -(-w // stride)
    ph = (kernel - 1) // 2
    padded = jnp.pad(x, ((ph, kernel - 1 - ph), (ph, kernel - 1 - ph), (0, 0)))
    patches = []
    for ky in range(kernel):
        for kx in range(kernel):
            sl = jax.lax.slice(
                padded, (ky, kx, 0), (ky + h, kx + w, c)
            )[::stride, ::stride, :]
            patches.append(sl.reshape(oh * ow, c))
    return jnp.concatenate(patches, axis=1)


def conv_block(x, layer: ConvLayer):
    """One conv layer via the L1 kernel (im2col lowering, Sec. IV-A)."""
    h, w, _ = x.shape
    oh, ow = -(-h // layer.stride), -(-w // layer.stride)
    lhs = _im2col(x, layer.kernel, layer.stride)
    # weights (outC, kh, kw, inC) → (kh*kw*inC, outC) matching im2col's
    # (ky, kx, c) patch order.
    wmat = jnp.asarray(
        np.transpose(layer.weights, (1, 2, 3, 0)).reshape(-1, layer.out_c)
    ).astype(jnp.int8)
    out = matmul_i8(
        lhs,
        wmat,
        jnp.asarray(layer.bias),
        multiplier=layer.multiplier,
        shift=layer.shift,
        relu=layer.relu,
    )
    return out.reshape(oh, ow, layer.out_c)


def forward(model: QuickstartModel, x):
    """Full quantized forward: (H, W, 3) int8 → (num_classes,) int32 logits."""
    for layer in model.layers:
        x = conv_block(x, layer)
    # Global average pool in the int domain (sum, then requant-free mean
    # as int32 logits — the host applies softmax/argmax).
    x32 = x.astype(jnp.int32)
    pooled = jnp.sum(x32, axis=(0, 1))
    return pooled


def forward_fn(model: QuickstartModel):
    """Jittable closure over the baked weights."""

    @functools.wraps(forward)
    def fn(x):
        return (forward(model, x),)

    return fn


def reference_forward(model: QuickstartModel, x: np.ndarray) -> np.ndarray:
    """Oracle forward using ref.conv2d_i8_ref — used by pytest to check the
    traced/AOT path end-to-end."""
    cur = jnp.asarray(x)
    for layer in model.layers:
        cur = ref.conv2d_i8_ref(
            cur,
            jnp.asarray(layer.weights),
            jnp.asarray(layer.bias),
            layer.multiplier,
            layer.shift,
            stride=layer.stride,
            relu=layer.relu,
        )
    return np.asarray(jnp.sum(cur.astype(jnp.int32), axis=(0, 1)))
