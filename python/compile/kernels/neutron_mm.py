"""L1 Pallas kernel: the Neutron dot-product array as an MXU-shaped
output-stationary INT8 matmul with fused requantization + activation.

Hardware adaptation (DESIGN.md §3): the paper's core is M=16 parallel
dot-product units of vector length N=16, output-stationary with A=2M
accumulators, fed by a data engine that broadcasts one operand. On the TPU
abstraction Pallas exposes, the same insight maps to:

  * the M×N unit grid      → one MXU-tile matmul per (BM, BN) output block;
  * the A-deep accumulator → the int32 VMEM scratch accumulated across the
                             K grid dimension (output-stationary: the
                             accumulator never leaves VMEM);
  * the shared-operand bus → BlockSpec index maps re-using the lhs block
                             across the N grid axis and the rhs block
                             across the M grid axis;
  * the activation engine  → fused requantize + ReLU on the final K step.

Runs with ``interpret=True`` (CPU PJRT cannot execute Mosaic custom-calls);
the TPU-side VMEM/MXU efficiency estimate lives in DESIGN.md §8.
"""

from __future__ import annotations

import functools

import jax

jax.config.update("jax_enable_x64", True)  # see ref.py — requant needs i64

import jax.numpy as jnp
from jax.experimental import pallas as pl

# Block sizes: multiples of the 128×128 MXU tile; K blocked at 128 so an
# int8 lhs block (BM×BK) + rhs block (BK×BN) + int32 accumulator (BM×BN)
# fit comfortably in VMEM: 64·128 + 128·128 + 64·128·4 ≈ 57 KiB per step.
BM, BK, BN = 64, 128, 128


def _mm_kernel(lhs_ref, rhs_ref, bias_ref, out_ref, acc_ref, *,
               multiplier: int, shift: int, relu: bool, k_steps: int):
    """One (m, n, k) grid step: accumulate lhs·rhs into the VMEM scratch;
    on the last K step, add bias, requantize, activate, write out."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = lhs_ref[...].astype(jnp.int32)
    b = rhs_ref[...].astype(jnp.int32)
    acc_ref[...] += jax.lax.dot_general(
        a, b, (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32
    )

    @pl.when(k == k_steps - 1)
    def _finish():
        acc = acc_ref[...] + bias_ref[...].astype(jnp.int32)[None, :]
        # Fixed-point requantization (matches rust Requant::apply and
        # ref.requant_apply bit-exactly).
        prod = acc.astype(jnp.int64) * jnp.int64(multiplier)
        high = (prod + jnp.int64(1 << 30)) >> jnp.int64(31)
        if shift <= 0:
            out = high << jnp.int64(-shift)
        else:
            out = (high + (jnp.int64(1) << jnp.int64(shift - 1))) >> jnp.int64(shift)
        out = out.astype(jnp.int32)
        if relu:
            out = jnp.maximum(out, 0)
        out_ref[...] = jnp.clip(out, -128, 127).astype(jnp.int8)


def _pad_to(x, axis: int, block: int):
    size = x.shape[axis]
    pad = (-size) % block
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(jax.jit, static_argnames=("multiplier", "shift", "relu"))
def matmul_i8(lhs, rhs, bias, *, multiplier: int, shift: int, relu: bool = False):
    """Quantized (M,K)×(K,N) int8 matmul with bias + requant [+ ReLU].

    Shapes are padded up to the block grid; the valid region is sliced
    back out, so any (M, K, N) works.
    """
    m, k = lhs.shape
    k2, n = rhs.shape
    assert k == k2, f"contraction mismatch {k} vs {k2}"
    lhs_p = _pad_to(_pad_to(lhs, 0, BM), 1, BK)
    rhs_p = _pad_to(_pad_to(rhs, 0, BK), 1, BN)
    bias_p = _pad_to(bias, 0, BN)
    mp, kp = lhs_p.shape
    _, np_ = rhs_p.shape
    k_steps = kp // BK
    grid = (mp // BM, np_ // BN, k_steps)

    out = pl.pallas_call(
        functools.partial(
            _mm_kernel,
            multiplier=multiplier,
            shift=shift,
            relu=relu,
            k_steps=k_steps,
        ),
        grid=grid,
        in_specs=[
            # lhs block re-used across the n grid axis (shared-operand bus).
            pl.BlockSpec((BM, BK), lambda i, j, kk: (i, kk)),
            # rhs block re-used across the m grid axis.
            pl.BlockSpec((BK, BN), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((BN,), lambda i, j, kk: (j,)),
        ],
        out_specs=pl.BlockSpec((BM, BN), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.int8),
        scratch_shapes=[pltpu_scratch((BM, BN), jnp.int32)],
        interpret=True,
    )(lhs_p, rhs_p, bias_p)
    return out[:m, :n]


def pltpu_scratch(shape, dtype):
    """VMEM scratch allocation (interpret-mode compatible)."""
    return pl.VMEM(shape, dtype) if hasattr(pl, "VMEM") else _vmem_fallback(shape, dtype)


def _vmem_fallback(shape, dtype):
    from jax.experimental.pallas import tpu as pltpu

    return pltpu.VMEM(shape, dtype)


def vmem_bytes_per_step() -> int:
    """Static VMEM footprint of one grid step (DESIGN.md §8 estimate)."""
    return BM * BK + BK * BN + BN * 4 + 2 * BM * BN * 4 + BM * BN


def mxu_utilization_estimate(m: int, k: int, n: int) -> float:
    """MXU utilization estimate from block padding (structure, not time)."""
    mp = -(-m // BM) * BM
    kp = -(-k // BK) * BK
    np_ = -(-n // BN) * BN
    return (m * k * n) / (mp * kp * np_)
