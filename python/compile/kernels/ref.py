"""Pure-jnp correctness oracle for the Neutron dot-product-array kernel.

Implements the exact INT8 quantized matmul semantics the L1 Pallas kernel
and the rust reference executor must reproduce bit-exactly:

    acc   = sum_k lhs_i8[m, k] * rhs_i8[k, n]  + bias_i32[n]      (int32)
    high  = round(acc * multiplier / 2**31)     (rounding high mul)
    out   = clamp_i8( rounding_shift_right(high, shift) [+ relu] )

The requantization pair ``(multiplier, shift)`` follows the fixed-point
decomposition in ``rust/src/ir/quant.rs`` (`Requant::from_real/apply`).
"""

from __future__ import annotations

import jax

# The requantization high-multiply needs true 64-bit integers; without x64
# jnp silently truncates to int32 and the python side would diverge from
# the rust runtime's i64 arithmetic on large accumulators.
jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np


def requant_from_real(real: float) -> tuple[int, int]:
    """Decompose a positive real multiplier into (mantissa_q31, shift)."""
    assert real > 0.0
    shift = 0
    r = float(real)
    while r < 0.5:
        r *= 2.0
        shift += 1
    while r >= 1.0:
        r /= 2.0
        shift -= 1
    multiplier = int(round(r * (1 << 31)))
    if multiplier == 1 << 31:
        multiplier //= 2
        shift -= 1
    return multiplier, shift


def requant_apply(acc, multiplier: int, shift: int):
    """Apply the fixed-point rescale to an int32 array (jnp or np).

    Mirrors ``Requant::apply`` in rust: rounding high multiply then
    rounding right shift (or left shift for negative shifts).
    """
    acc64 = acc.astype(jnp.int64)
    prod = acc64 * jnp.int64(multiplier)
    high = (prod + jnp.int64(1 << 30)) >> jnp.int64(31)
    if shift <= 0:
        out = high << jnp.int64(-shift)
    else:
        round_ = jnp.int64(1) << jnp.int64(shift - 1)
        out = (high + round_) >> jnp.int64(shift)
    return out.astype(jnp.int32)


def matmul_i8_ref(lhs, rhs, bias, multiplier: int, shift: int, relu: bool = False):
    """Oracle: quantized (M,K)x(K,N) matmul with bias + requant [+ relu].

    lhs: int8 (M, K); rhs: int8 (K, N); bias: int32 (N,)
    Returns int8 (M, N).
    """
    acc = jnp.matmul(
        lhs.astype(jnp.int32), rhs.astype(jnp.int32), preferred_element_type=jnp.int32
    )
    acc = acc + bias.astype(jnp.int32)[None, :]
    out = requant_apply(acc, multiplier, shift)
    if relu:
        out = jnp.maximum(out, 0)
    return jnp.clip(out, -128, 127).astype(jnp.int8)


def conv2d_i8_ref(ifmap, weights, bias, multiplier: int, shift: int,
                  stride: int = 1, relu: bool = False):
    """Oracle for a SAME-padded int8 conv: (H,W,Cin) ⊛ (Cout,kh,kw,Cin).

    Lowered the way the compiler does (Sec. IV-A): im2col to a matmul on
    the dot-product array. Test scale only.
    """
    h, w, cin = ifmap.shape
    cout, kh, kw, _ = weights.shape
    oh, ow = -(-h // stride), -(-w // stride)
    ph, pw = (kh - 1) // 2, (kw - 1) // 2
    padded = jnp.pad(
        ifmap.astype(jnp.int32), ((ph, kh - 1 - ph), (pw, kw - 1 - pw), (0, 0))
    )
    cols = []
    for oy in range(oh):
        for ox in range(ow):
            patch = padded[oy * stride:oy * stride + kh, ox * stride:ox * stride + kw, :]
            cols.append(patch.reshape(-1))
    lhs = jnp.stack(cols).astype(jnp.int8)              # (oh*ow, kh*kw*cin)
    rhs = weights.reshape(cout, -1).T.astype(jnp.int8)  # (kh*kw*cin, cout)
    out = matmul_i8_ref(lhs, rhs, bias, multiplier, shift, relu)
    return out.reshape(oh, ow, cout)


def random_quant_case(rng: np.random.Generator, m: int, k: int, n: int):
    """Deterministic random test case for the kernel sweeps."""
    lhs = rng.integers(-128, 128, size=(m, k), dtype=np.int8)
    rhs = rng.integers(-128, 128, size=(k, n), dtype=np.int8)
    bias = rng.integers(-(1 << 12), 1 << 12, size=(n,), dtype=np.int32)
    real = float(rng.uniform(2e-4, 0.05))  # realistic conv rescale range
    mult, shift = requant_from_real(real)
    return lhs, rhs, bias, mult, shift
