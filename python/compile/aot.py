"""AOT export: lower the L2 model (and a standalone L1 kernel) to HLO TEXT
for the rust PJRT runtime.

HLO *text* — not ``lowered.compile().serialize()`` — is the interchange
format: jax ≥ 0.5 emits HloModuleProto with 64-bit instruction ids, which
the image's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the
text parser reassigns ids and round-trips cleanly (see
/opt/xla-example/README.md).

Outputs (under ``artifacts/``):
  model.hlo.txt        — quickstart CNN forward (weights baked in)
  kernel_mm.hlo.txt    — standalone neutron_mm matmul (runtime unit tests)
  manifest.txt         — shapes/dtypes + expected outputs for self-checks

Python runs ONCE at build time (``make artifacts``); the rust binary is
self-contained afterwards.
"""

from __future__ import annotations

import argparse
import os

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as model_mod
from .kernels import ref
from .kernels.neutron_mm import matmul_i8


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def export_model(out_dir: str, seed: int = 7, input_hw: int = 32) -> dict:
    """Lower the quickstart model; return manifest entries."""
    m = model_mod.build_quickstart(seed=seed, input_hw=input_hw)
    fn = model_mod.forward_fn(m)
    spec = jax.ShapeDtypeStruct((m.input_hw, m.input_hw, m.input_c), jnp.int8)
    lowered = jax.jit(fn).lower(spec)
    text = to_hlo_text(lowered)
    path = os.path.join(out_dir, "model.hlo.txt")
    with open(path, "w") as f:
        f.write(text)

    # Self-check vector: run the traced fn and the pure oracle on a
    # deterministic input; both go into the manifest so the rust runtime
    # can assert its numerics without Python present.
    rng = np.random.default_rng(99)
    x = rng.integers(-128, 128, size=spec.shape, dtype=np.int8)
    traced = np.asarray(fn(jnp.asarray(x))[0])
    oracle = model_mod.reference_forward(m, x)
    assert np.array_equal(traced, oracle), "traced forward != oracle"
    return {
        "model.input_shape": "x".join(map(str, spec.shape)),
        "model.input_seed": "99",
        "model.num_classes": str(m.num_classes),
        "model.expected_logits": ",".join(map(str, traced.tolist())),
        "model.path": "model.hlo.txt",
    }


# Fixed kernel-artifact geometry (runtime unit test shape).
KM, KK, KN = 32, 64, 48
K_MULT, K_SHIFT = ref.requant_from_real(0.0125)


def export_kernel(out_dir: str) -> dict:
    """Lower a standalone neutron_mm instance with runtime-fed operands."""

    def fn(lhs, rhs, bias):
        return (
            matmul_i8(lhs, rhs, bias, multiplier=K_MULT, shift=K_SHIFT, relu=False),
        )

    lhs_s = jax.ShapeDtypeStruct((KM, KK), jnp.int8)
    rhs_s = jax.ShapeDtypeStruct((KK, KN), jnp.int8)
    bias_s = jax.ShapeDtypeStruct((KN,), jnp.int32)
    lowered = jax.jit(fn).lower(lhs_s, rhs_s, bias_s)
    text = to_hlo_text(lowered)
    path = os.path.join(out_dir, "kernel_mm.hlo.txt")
    with open(path, "w") as f:
        f.write(text)

    # Deterministic check vector.
    rng = np.random.default_rng(1234)
    lhs, rhs, bias, _, _ = ref.random_quant_case(rng, KM, KK, KN)
    want = np.asarray(ref.matmul_i8_ref(lhs, rhs, bias, K_MULT, K_SHIFT))
    return {
        "kernel.m": str(KM),
        "kernel.k": str(KK),
        "kernel.n": str(KN),
        "kernel.seed": "1234",
        "kernel.multiplier": str(K_MULT),
        "kernel.shift": str(K_SHIFT),
        "kernel.expected_row0": ",".join(map(str, want[0].tolist())),
        "kernel.path": "kernel_mm.hlo.txt",
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts/model.hlo.txt",
                    help="path of the model artifact (its directory receives all artifacts)")
    ap.add_argument("--seed", type=int, default=7)
    ap.add_argument("--input-hw", type=int, default=32)
    args = ap.parse_args()

    out_dir = os.path.dirname(os.path.abspath(args.out)) or "."
    os.makedirs(out_dir, exist_ok=True)

    manifest = {}
    manifest.update(export_model(out_dir, seed=args.seed, input_hw=args.input_hw))
    manifest.update(export_kernel(out_dir))
    with open(os.path.join(out_dir, "manifest.txt"), "w") as f:
        for k in sorted(manifest):
            f.write(f"{k}={manifest[k]}\n")
    print(f"wrote artifacts to {out_dir}: {sorted(os.listdir(out_dir))}")


if __name__ == "__main__":
    main()
