#!/usr/bin/env bash
# Tier-1 verify in one command: release build, test suite, docs, format check.
set -euo pipefail
cd "$(dirname "$0")"

# Every integration test must actually run: `autotests = false` means a
# rust/tests/*.rs file without a [[test]] entry in Cargo.toml silently
# never executes.
for t in rust/tests/*.rs; do
    name=$(basename "$t" .rs)
    if ! grep -q "name = \"$name\"" Cargo.toml; then
        echo "ERROR: $t has no [[test]] entry in Cargo.toml — it would never run" >&2
        exit 1
    fi
done

cargo build --release
cargo build --release --benches
cargo test -q

# Trace record → replay smoke: a recorded `neutron serve` run must replay
# to a byte-identical report (the virtual-clock contract, end to end
# through the CLI and the JSONL file), and the trace must validate.
smoke_dir=$(mktemp -d)
trap 'rm -rf "$smoke_dir"' EXIT
./target/release/neutron serve --requests 32 --instances 2 --queue-capacity 8 \
    --max-batch 4 --dynamic-batch --seed 11 --mean-gap-cycles 200000 \
    --record "$smoke_dir/trace.jsonl" > "$smoke_dir/recorded.txt"
./target/release/neutron replay "$smoke_dir/trace.jsonl" > "$smoke_dir/replayed.txt"
diff "$smoke_dir/recorded.txt" "$smoke_dir/replayed.txt"
./target/release/neutron validate "$smoke_dir/trace.jsonl" > /dev/null
# Degenerate knobs must be rejected loudly, not silently reinterpreted.
if ./target/release/neutron serve --max-batch 0 >/dev/null 2>&1; then
    echo "ERROR: 'neutron serve --max-batch 0' should have been rejected" >&2
    exit 1
fi
echo "trace record/replay smoke OK"

# Calibration loop smoke: record → validate (save the fit) → tune → replay.
# The tune line reports overall per-op MAPE before (uncalibrated recompile)
# and after (calibrated recompile, replayed); the calibrated model must not
# regress (0.5 percentage points of recompile jitter tolerated — a real
# regression is tens of points).
./target/release/neutron record "$smoke_dir/tune.jsonl" --requests 24 --instances 2 \
    --seed 5 --mean-gap-cycles 300000 > /dev/null
./target/release/neutron validate "$smoke_dir/tune.jsonl" \
    --save-calibration "$smoke_dir/cal.json" > /dev/null
./target/release/neutron tune --trace "$smoke_dir/tune.jsonl" > "$smoke_dir/tune.txt"
tune_line=$(grep '^tune: ' "$smoke_dir/tune.txt")
echo "$tune_line"
mape_before=$(printf '%s\n' "$tune_line" | sed -n 's/.*mape_before_pct=\([0-9.]*\).*/\1/p')
mape_after=$(printf '%s\n' "$tune_line" | sed -n 's/.*mape_after_pct=\([0-9.]*\).*/\1/p')
if [ -z "$mape_before" ] || [ -z "$mape_after" ]; then
    echo "ERROR: could not parse tune summary line" >&2
    exit 1
fi
if ! awk -v after="$mape_after" -v before="$mape_before" 'BEGIN { exit !(after <= before + 0.5) }'; then
    echo "ERROR: calibrated recompile regressed per-op MAPE ($mape_before% -> $mape_after%)" >&2
    exit 1
fi
# The saved fit loads back into a calibrated, speed-scaled replay.
./target/release/neutron replay "$smoke_dir/tune.jsonl" --speed 2.0 \
    --calibration "$smoke_dir/cal.json" > /dev/null
echo "calibration tune smoke OK ($mape_before% -> $mape_after% MAPE)"

# Artifact store smoke: save → restart → load. A `neutron serve
# --artifact-dir` run compiles cold once and persists `.npu` artifacts; a
# restarted run must warm purely from disk — zero CP solves ("/ 0 misses"
# with every model loaded, none compiled) and a byte-identical report.
art_dir="$smoke_dir/npu"
./target/release/neutron compile --model mobilenet-v3 --save "$art_dir" > /dev/null 2>&1
./target/release/neutron compile --model mobilenet-v3 --load "$art_dir" \
    | grep -q "0 CP solves"
./target/release/neutron serve --requests 24 --instances 2 --seed 9 \
    --mean-gap-cycles 300000 --artifact-dir "$art_dir" > "$smoke_dir/serve_cold.txt" 2> /dev/null
./target/release/neutron serve --requests 24 --instances 2 --seed 9 \
    --mean-gap-cycles 300000 --artifact-dir "$art_dir" \
    > "$smoke_dir/serve_warm.txt" 2> "$smoke_dir/serve_warm.err"
diff "$smoke_dir/serve_cold.txt" "$smoke_dir/serve_warm.txt"
grep -q "/ 0 misses" "$smoke_dir/serve_warm.txt"
grep -q "3 loaded, 0 compiled" "$smoke_dir/serve_warm.err"
echo "artifact store smoke OK (restart served with zero cold compiles)"

# Pipelining + residency smoke: a recorded pipelined/resident run must
# replay byte-identically through the v2 trace format, and contradictory
# knobs must be rejected loudly.
./target/release/neutron serve --requests 32 --instances 2 --seed 17 \
    --mean-gap-cycles 200000 --pipeline --residency --warm-routing \
    --record "$smoke_dir/pipe.jsonl" > "$smoke_dir/pipe_recorded.txt"
./target/release/neutron replay "$smoke_dir/pipe.jsonl" > "$smoke_dir/pipe_replayed.txt"
diff "$smoke_dir/pipe_recorded.txt" "$smoke_dir/pipe_replayed.txt"
if ./target/release/neutron serve --warm-routing >/dev/null 2>&1; then
    echo "ERROR: 'neutron serve --warm-routing' without --residency should have been rejected" >&2
    exit 1
fi
echo "pipelining + residency smoke OK"

# GenAI decode smoke: a recorded autoregressive serve run (prefill/decode
# split, KV residency, continuous batching) must replay to a byte-identical
# report through the v3 trace format, the decode context-curve fit must
# render, and contradictory decode knobs must be rejected loudly.
./target/release/neutron serve --models gpt-tiny --decode --requests 16 \
    --instances 1 --seed 23 --mean-gap-cycles 100000 --prompt-tokens 6 \
    --decode-tokens 5 --max-context 16 --continuous-batch --residency \
    --record "$smoke_dir/decode.jsonl" > "$smoke_dir/decode_recorded.txt"
grep -q "genai:" "$smoke_dir/decode_recorded.txt"
./target/release/neutron replay "$smoke_dir/decode.jsonl" > "$smoke_dir/decode_replayed.txt"
diff "$smoke_dir/decode_recorded.txt" "$smoke_dir/decode_replayed.txt"
./target/release/neutron validate --decode-curve --max-context 16 \
    | grep -q "context curve"
if ./target/release/neutron serve --continuous-batch >/dev/null 2>&1; then
    echo "ERROR: 'neutron serve --continuous-batch' without --decode should have been rejected" >&2
    exit 1
fi
if ./target/release/neutron serve --models gpt-tiny --decode --prompt-tokens 20 \
    --decode-tokens 20 --max-context 16 >/dev/null 2>&1; then
    echo "ERROR: prompt+decode tokens above --max-context should have been rejected" >&2
    exit 1
fi
echo "genai decode smoke OK"

# Energy accounting smoke: a metered serve run reports joules, records a
# v4 trace that replays byte-identically (joules included), fits an
# improve-only per-channel energy calibration through validate/tune, and
# prices the zoo via `list`. The meter must be invisible when off, and
# contradictory energy knobs must be rejected loudly.
./target/release/neutron serve --requests 24 --instances 2 --seed 29 \
    --mean-gap-cycles 200000 --max-batch 4 --energy --energy-mode stretch \
    --record "$smoke_dir/energy.jsonl" > "$smoke_dir/energy_recorded.txt"
grep -q "energy:" "$smoke_dir/energy_recorded.txt"
./target/release/neutron replay "$smoke_dir/energy.jsonl" > "$smoke_dir/energy_replayed.txt"
diff "$smoke_dir/energy_recorded.txt" "$smoke_dir/energy_replayed.txt"
./target/release/neutron serve --requests 8 --seed 29 > "$smoke_dir/unmetered.txt"
if grep -q "energy:" "$smoke_dir/unmetered.txt"; then
    echo "ERROR: an unmetered serve run must not print an energy summary line" >&2
    exit 1
fi
./target/release/neutron validate --energy "$smoke_dir/energy.jsonl" \
    --save-energy-calibration "$smoke_dir/ecal.json" > /dev/null
./target/release/neutron tune --energy --trace "$smoke_dir/energy.jsonl" \
    > "$smoke_dir/energy_tune.txt"
etune_line=$(grep '^tune-energy: ' "$smoke_dir/energy_tune.txt")
echo "$etune_line"
emape_before=$(printf '%s\n' "$etune_line" | sed -n 's/.*mape_before_pct=\([0-9.]*\).*/\1/p')
emape_after=$(printf '%s\n' "$etune_line" | sed -n 's/.*mape_after_pct=\([0-9.]*\).*/\1/p')
if [ -z "$emape_before" ] || [ -z "$emape_after" ]; then
    echo "ERROR: could not parse tune-energy summary line" >&2
    exit 1
fi
if ! awk -v after="$emape_after" -v before="$emape_before" 'BEGIN { exit !(after <= before + 0.001) }'; then
    echo "ERROR: energy calibration worsened per-channel MAPE ($emape_before% -> $emape_after%)" >&2
    exit 1
fi
./target/release/neutron list --energy-calibration "$smoke_dir/ecal.json" \
    | grep -q "J/inf"
if ./target/release/neutron serve --energy-budget 0.5 >/dev/null 2>&1; then
    echo "ERROR: 'neutron serve --energy-budget' without --energy should have been rejected" >&2
    exit 1
fi
if ./target/release/neutron serve --energy-mode stretch >/dev/null 2>&1; then
    echo "ERROR: 'neutron serve --energy-mode' without --energy should have been rejected" >&2
    exit 1
fi
if ./target/release/neutron serve --energy --energy-mode sprint >/dev/null 2>&1; then
    echo "ERROR: unknown --energy-mode should have been rejected" >&2
    exit 1
fi
echo "energy accounting smoke OK ($emape_before% -> $emape_after% energy MAPE)"

# Solver hot-path bench (includes the warm-vs-cold budget sweep and the
# old-vs-new propagation-engine comparison with its ≤-node acceptance
# assertion); the measurements land in BENCH_solver_hotpath.json.
cargo bench --bench solver_hotpath -- --json "$PWD/BENCH_solver_hotpath.json" \
    > /dev/null
# The engine-comparison rows must actually land in the JSON — a bench
# refactor that drops them would silently retire the equivalence bound.
grep -q '"name":"engine_cmp_' BENCH_solver_hotpath.json
grep -q '"name":"engine_cmp_scheduling_' BENCH_solver_hotpath.json
echo "solver hotpath bench OK (BENCH_solver_hotpath.json)"

# Serve throughput bench (includes the pipelining × residency sweep and
# its makespan-monotonicity assertion); the measurements land in
# BENCH_serve_throughput.json.
cargo bench --bench serve_throughput -- --json "$PWD/BENCH_serve_throughput.json" \
    > /dev/null
echo "serve throughput bench OK (BENCH_serve_throughput.json)"

# GenAI decode bench (includes the continuous-vs-request-boundary sweep
# and its strict makespan + TPOT assertions); the measurements land in
# BENCH_genai_decode.json.
cargo bench --bench genai_decode -- --json "$PWD/BENCH_genai_decode.json" \
    > /dev/null
echo "genai decode bench OK (BENCH_genai_decode.json)"

# Energy sweep bench (race-to-idle vs stretch Pareto points, budget
# shedding, the zoo's analytic J/inference table — with the
# different-(makespan, joules)-points assertion); the measurements land
# in BENCH_energy_sweep.json.
cargo bench --bench energy_sweep -- --json "$PWD/BENCH_energy_sweep.json" \
    > /dev/null
echo "energy sweep bench OK (BENCH_energy_sweep.json)"

# Docs must not rot: fail on any rustdoc warning (missing docs in the
# serve module, broken intra-doc links, …). Vendored stand-ins are not
# documented (--no-deps + explicit package).
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps --package eiq_neutron
if cargo fmt --version >/dev/null 2>&1; then
    cargo fmt --check
else
    echo "cargo fmt unavailable — skipping format check"
fi
echo "tier-1 verify OK"
