#!/usr/bin/env bash
# Tier-1 verify in one command: release build, test suite, docs, format check.
set -euo pipefail
cd "$(dirname "$0")"

cargo build --release
cargo build --release --benches
cargo test -q
# Docs must not rot: fail on any rustdoc warning (missing docs in the
# serve module, broken intra-doc links, …). Vendored stand-ins are not
# documented (--no-deps + explicit package).
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps --package eiq_neutron
if cargo fmt --version >/dev/null 2>&1; then
    cargo fmt --check
else
    echo "cargo fmt unavailable — skipping format check"
fi
echo "tier-1 verify OK"
