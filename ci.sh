#!/usr/bin/env bash
# Tier-1 verify in one command: release build, test suite, format check.
set -euo pipefail
cd "$(dirname "$0")"

cargo build --release
cargo build --release --benches
cargo test -q
if cargo fmt --version >/dev/null 2>&1; then
    cargo fmt --check
else
    echo "cargo fmt unavailable — skipping format check"
fi
echo "tier-1 verify OK"
