#!/usr/bin/env bash
# Tier-1 verify in one command: release build, test suite, docs, format check.
set -euo pipefail
cd "$(dirname "$0")"

cargo build --release
cargo build --release --benches
cargo test -q

# Trace record → replay smoke: a recorded `neutron serve` run must replay
# to a byte-identical report (the virtual-clock contract, end to end
# through the CLI and the JSONL file), and the trace must validate.
smoke_dir=$(mktemp -d)
trap 'rm -rf "$smoke_dir"' EXIT
./target/release/neutron serve --requests 32 --instances 2 --queue-capacity 8 \
    --max-batch 4 --dynamic-batch --seed 11 --mean-gap-cycles 200000 \
    --record "$smoke_dir/trace.jsonl" > "$smoke_dir/recorded.txt"
./target/release/neutron replay "$smoke_dir/trace.jsonl" > "$smoke_dir/replayed.txt"
diff "$smoke_dir/recorded.txt" "$smoke_dir/replayed.txt"
./target/release/neutron validate "$smoke_dir/trace.jsonl" > /dev/null
# Degenerate knobs must be rejected loudly, not silently reinterpreted.
if ./target/release/neutron serve --max-batch 0 >/dev/null 2>&1; then
    echo "ERROR: 'neutron serve --max-batch 0' should have been rejected" >&2
    exit 1
fi
echo "trace record/replay smoke OK"
# Docs must not rot: fail on any rustdoc warning (missing docs in the
# serve module, broken intra-doc links, …). Vendored stand-ins are not
# documented (--no-deps + explicit package).
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps --package eiq_neutron
if cargo fmt --version >/dev/null 2>&1; then
    cargo fmt --check
else
    echo "cargo fmt unavailable — skipping format check"
fi
echo "tier-1 verify OK"
